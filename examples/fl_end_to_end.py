"""End-to-end driver: the paper's full experiment at laptop scale.

Trains the selected model family (``--task``: the Fashion-MNIST CNN by
default, or any other entry in ``repro.fl.tasks.TASKS`` such as
``transformer_lm`` / ``fmnist_mlp``) with all the paper's methods for a few
hundred simulated seconds (several hundred aggregation rounds for the async
methods) and prints the Table-5-style comparison.  Runs on the
strategy-based ``FLEngine`` by default; ``--backend legacy`` selects the
monolithic reference simulator, ``--cohort 32`` enables vectorized
cohort training, ``--scheduler batched`` swaps in the array-backed
batched event scheduler (bit-identical histories), and ``--handler-mode
wave`` adds the vectorized per-wave handlers on top of it (documented
relaxed parity, built for 10^6-device fleets).

``--codec-policy tier_aware`` demos the adaptive per-device codec layer: a
heterogeneous 3-tier fleet where the per-tier Alg. 5 search gives each
bandwidth tier its own (p_s, p_q) operating point.

``--fleet`` switches to the multi-task fleet demo
(``repro.fl.fleet.MultiTaskEngine``): four model families — the FMNIST
CNN, the transformer LM, the MoE LM and the SSM LM — train as concurrent
FL jobs over ONE shared device fleet and one event loop, each job with
its own protocol, admission gate, codec and byte meters; ``--assigner``
picks the device->job routing rule from ``ASSIGNERS``.

  PYTHONPATH=src python examples/fl_end_to_end.py [--budget 120] [--noniid]
  PYTHONPATH=src python examples/fl_end_to_end.py --task transformer_lm
  PYTHONPATH=src python examples/fl_end_to_end.py --codec-policy tier_aware
  PYTHONPATH=src python examples/fl_end_to_end.py --fleet --budget 4 --assigner adaptive
"""
import argparse
import time

from repro.core.codecs import CODECS
from repro.core.dynamic import make_schedule
from repro.core.server import SERVERS
from repro.fl.fleet import ASSIGNERS, FleetConfig, build_fleet
from repro.fl.policies import POLICIES
from repro.fl.protocols import (best_acc_within, make_setup,
                                profile_compression, run_method)
from repro.fl.simulator import ScenarioConfig, SimConfig, TierSpec
from repro.fl.tasks import TASKS


def run_fleet_demo(args) -> None:
    """Four heterogeneous FL jobs co-training on one shared fleet."""
    specs = [
        SimConfig(method="teasq", task="fmnist_cnn", epochs=1,
                  p_s=0.25, p_q=8),
        SimConfig(method="teastatic", task="transformer_lm", epochs=1,
                  p_s=0.25, p_q=8),
        SimConfig(method="fedasync", task="moe_lm", epochs=1),
        SimConfig(method="teasq", task="ssm_lm", epochs=1,
                  p_s=0.25, p_q=8),
    ]
    cfg = FleetConfig(tasks=specs, n_devices=args.devices,
                      scheduler=args.scheduler, assigner=args.assigner,
                      handler_mode=args.handler_mode)
    fleet = build_fleet(cfg, iid=not args.noniid,
                        n_train=args.samples, n_test=args.samples // 5)
    t0 = time.time()
    hists = fleet.run(time_budget=args.budget, eval_every=4)
    wall = time.time() - t0
    print(f"\n{args.assigner} assigner, {args.devices} shared devices, "
          f"{args.budget:.0f}s virtual budget, wall={wall:.0f}s")
    print("job             method     rounds  best_acc  upload_MB  grants")
    for spec, rt, hist in zip(specs, fleet.runtimes, hists):
        best = max(h.accuracy for h in hist)
        print(f"{spec.task:15s} {spec.method:10s} {hist[-1].round:5d}   "
              f"{best:.3f}   {hist[-1].bytes_up / 1e6:8.1f}  "
              f"{rt.stats.dispatches:6d}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=120.0,
                    help="simulated seconds")
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--samples", type=int, default=12000)
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--backend", choices=("engine", "legacy"),
                    default="engine",
                    help="strategy-based engine (default) or legacy sim")
    ap.add_argument("--cohort", type=int, default=0,
                    help="engine cohort size (>0 = vectorized local "
                         "training for the async methods)")
    ap.add_argument("--scheduler", choices=("heap", "batched"),
                    default="heap",
                    help="engine event loop (SimConfig.scheduler): the "
                         "reference one-event-at-a-time heap, or the "
                         "array-backed batched scheduler — bit-identical "
                         "histories, built for 10^4-10^5-device fleets "
                         "(default: %(default)s)")
    ap.add_argument("--handler-mode", choices=("serial", "wave"),
                    default="serial",
                    help="batched-scheduler event handlers "
                         "(SimConfig.handler_mode): 'serial' replays the "
                         "heap loop event-by-event (bit-identical, pinned); "
                         "'wave' dispatches each selected batch as arrays — "
                         "documented relaxed parity, built for 10^6-device "
                         "fleets; requires --scheduler batched "
                         "(default: %(default)s)")
    ap.add_argument("--server", choices=sorted(SERVERS), default="single",
                    help="engine aggregation backend (SimConfig.server, "
                         "repro.core.server.SERVERS): 'single' is the "
                         "paper's one-host TeasqServer; 'sharded' runs the "
                         "stacked Eqs. 6-10 cache reduction as a shard_map "
                         "over the host device mesh (parity-pinned by "
                         "tests/test_sharded_server.py; spread the mesh "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N) (default: %(default)s)")
    ap.add_argument("--task", choices=sorted(TASKS), default="fmnist_cnn",
                    help="model family to train (repro.fl.tasks.TASKS): the "
                         "paper's FMNIST CNN, a tiny transformer LM on a "
                         "synthetic token stream, or the FMNIST MLP — every "
                         "task runs under every protocol (default: "
                         "%(default)s)")
    ap.add_argument("--codec", choices=sorted(CODECS), default="dense",
                    help="wire codec for the compressed methods: TEASQ "
                         "defaults to 'dense' (the Algs. 3-4 reference codec "
                         "priced as the packed stream); 'packed' transmits "
                         "the real bit-packed bytes (bit-identical result), "
                         "'threshold' the approximate in-graph channel, "
                         "'identity' disables compression (default: "
                         "%(default)s)")
    ap.add_argument("--codec-policy", choices=sorted(POLICIES),
                    default="static",
                    help="per-device codec policy (SimConfig.codec_policy, "
                         "repro.fl.policies.POLICIES): 'static' keeps each "
                         "protocol's global Alg. 5 operating point; "
                         "'tier_aware' installs a heterogeneous 3-tier "
                         "fleet and runs the per-tier Alg. 5 search so "
                         "slow-bandwidth tiers ship aggressively packed "
                         "updates while full-rate tiers stay near-dense; "
                         "'staleness_aware' adds compression notches for "
                         "chronically stale devices (default: %(default)s)")
    ap.add_argument("--fleet", action="store_true",
                    help="multi-task fleet demo (repro.fl.fleet): four "
                         "model families co-train as concurrent FL jobs "
                         "over one shared device fleet and one event loop "
                         "instead of the single-job method comparison")
    ap.add_argument("--assigner", choices=sorted(ASSIGNERS),
                    default="adaptive",
                    help="fleet device->job routing rule "
                         "(repro.fl.fleet.ASSIGNERS); only used with "
                         "--fleet (default: %(default)s)")
    args = ap.parse_args()

    if args.fleet:
        run_fleet_demo(args)
        return

    iid = not args.noniid
    data, parts, w0 = make_setup(n_devices=args.devices, iid=iid,
                                 n_train=args.samples,
                                 n_test=args.samples // 5, task=args.task)
    si, qi, trace = profile_compression(w0, data, theta=0.03, task=args.task)
    sched = make_schedule(si, qi, total_rounds=80)
    print(f"[alg5] searched static point: p_s={trace[-1][0] if trace else 1.0}"
          f" (idx {si}), p_q idx {qi}; {len(trace)} profile evals")

    policy_kw = {}
    if args.codec_policy != "static":
        # a demo heterogeneous fleet for the adaptive policies: a quarter of
        # devices at full rate, the rest on progressively slower links
        tiers = [TierSpec(0.25, 1.0, 1.0, "fast"),
                 TierSpec(0.375, 1.5, 0.5, "mid"),
                 TierSpec(0.375, 2.5, 0.125, "slow")]
        policy_kw = dict(codec_policy=args.codec_policy,
                         scenario=ScenarioConfig(tiers=tiers))
        if args.codec_policy == "tier_aware":
            tier_points, _ = profile_compression(w0, data, theta=0.03,
                                                 task=args.task, tiers=tiers)
            policy_kw["tier_points"] = tier_points
            print(f"[alg5] per-tier points "
                  f"{[t.name for t in tiers]}: {tier_points}")

    rows = []
    for method, kw in [("fedavg", {}),
                       ("fedasync", {}),
                       ("tea", {}),
                       ("teastatic", dict(p_s=0.25, p_q=8)),
                       ("teasq", dict(p_s=0.25, p_q=8, schedule=sched))]:
        t0 = time.time()
        hist = run_method(method, data, parts, w0, iid=iid,
                          time_budget=args.budget, epochs=1, eval_every=4,
                          backend=args.backend, cohort_size=args.cohort,
                          scheduler=args.scheduler,
                          handler_mode=args.handler_mode,
                          server=args.server,
                          codec=args.codec, task=args.task, **policy_kw,
                          **kw)
        best = max(h.accuracy for h in hist)
        rows.append((method, hist[-1].round, best,
                     hist[-1].bytes_up / 1e6, time.time() - t0))
        print(f"[{method:10s}] rounds={rows[-1][1]:4d} best_acc={best:.3f} "
              f"up={rows[-1][3]:.1f}MB wall={rows[-1][4]:.0f}s", flush=True)

    print("\nmethod      rounds  best_acc  upload_MB")
    for m, r, a, up, _ in rows:
        print(f"{m:10s}  {r:5d}   {a:.3f}    {up:8.1f}")


if __name__ == "__main__":
    main()
