"""Batched serving of a small model: prefill + KV-cache decode.

Demonstrates the serving path used by the decode dry-run shapes, at smoke
scale on CPU, for a dense, an MoE and an SSM architecture.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.launch.serve import generate
from repro.models import transformer as T

for arch in ["qwen3-1.7b", "phi3.5-moe-42b-a6.6b", "mamba2-370m"]:
    cfg = get_smoke_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)), jnp.int32)
    t0 = time.time()
    seqs = generate(params, cfg, prompts, gen=12, temperature=0.8)
    dt = time.time() - t0
    print(f"[{cfg.name:28s}] {seqs.shape[0]}x{seqs.shape[1]} tokens "
          f"in {dt:5.2f}s — sample: {np.asarray(seqs[0, -6:]).tolist()}")
