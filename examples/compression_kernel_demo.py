"""The Pallas compression kernel vs the packed wire format, side by side.

  PYTHONPATH=src python examples/compression_kernel_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import PackedBitstreamCodec
from repro.core.compression import (compress_pytree, pytree_dense_bytes,
                                    pytree_wire_bytes)
from repro.kernels.ops import compress_roundtrip
from repro.models.cnn import init_cnn

w = init_cnn(jax.random.PRNGKey(0))
dense = pytree_dense_bytes(w)

print("p_s    p_q   wire_KB  ratio   packed_KB  kernel_mse")
for p_s, p_q in [(1.0, 32), (0.5, 16), (0.25, 8), (0.1, 8), (0.05, 4)]:
    c = compress_pytree(w, p_s, p_q)
    wire = pytree_wire_bytes(c)
    # the real byte stream (codec API): len() must equal the analytic price
    packed = len(PackedBitstreamCodec(p_s, p_q).encode(w).payload)
    assert packed == wire, (packed, wire)
    # kernel path (block-local Top-K, interpret mode on CPU)
    mses = []
    for leaf in jax.tree.leaves(w):
        if leaf.size < 32:
            continue
        y = compress_roundtrip(leaf, p_s=p_s, bits=min(p_q, 8), block=4096)
        mses.append(float(jnp.mean((y - leaf) ** 2)))
    print(f"{p_s:4.2f}  {p_q:4d}  {wire/1024:7.1f}  {dense/wire:5.1f}x  "
          f"{packed/1024:9.1f}  {np.mean(mses):.2e}")
