"""The paper's technique on a (simulated) pod: TEASQ-Fed rounds as a single
jit-compiled step over a device mesh, with compressed delta exchange.

Uses 8 virtual host devices (set before jax import) to build a 4x2
(data=fed groups x model) mesh — the same code path the 512-chip dry-run
lowers, executable on CPU.

  PYTHONPATH=src python examples/multipod_fed_round.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.fed_step import FedConfig, fed_wire_bytes, make_fed_train_step
from repro.models import transformer as T
from repro.sharding.rules import Rules, use_rules

cfg = get_smoke_config("smollm-135m")
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = Rules(mesh)

params = T.init_model(jax.random.PRNGKey(0), cfg)
fed = FedConfig(n_groups=4, local_steps=2, lr=1e-2, schedule="gather_q",
                p_s=0.25, p_q=8)
step = jax.jit(make_fed_train_step(lambda p, b: T.lm_loss(p, b, cfg)[0], fed))

rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (16, 64)), jnp.int32)}
# groups at different staleness, as the async cache would present them
stale = jnp.asarray([0, 1, 0, 3], jnp.int32)

wire = fed_wire_bytes(params, fed, 4)
print(f"[wire] per-round exchange: dense f32 {wire['dense_f32']/1e6:.1f}MB "
      f"-> int8 {wire['dense_quant']/1e6:.1f}MB "
      f"-> packed sparse {wire['packed_sparse_quant']/1e6:.1f}MB "
      f"({wire['compression_x']:.1f}x)")

with use_rules(rules), mesh:
    for i in range(5):
        t0 = time.time()
        params, m = step(params, batch, stale)
        jax.block_until_ready(m["local_loss"])
        print(f"[round {i}] loss={float(m['local_loss']):.4f} "
              f"alpha_t={float(m['alpha_t']):.3f} "
              f"|delta|={float(m['delta_norm']):.3f} "
              f"({time.time()-t0:.2f}s on {mesh.devices.size} devices)")
