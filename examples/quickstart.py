"""Quickstart: the TEASQ-Fed core API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import roundtrip_pytree, pytree_dense_bytes
from repro.core.dynamic import make_schedule
from repro.core.server import ServerConfig, TeasqServer
from repro.core.staleness import staleness_weight
from repro.fl.protocols import make_setup, profile_compression, run_method

# 1. The compression operator (Alg. 3/4): Top-K + QSGD round trip ---------
w = {"layer": jnp.asarray(np.random.randn(64, 64), jnp.float32)}
w_hat, wire_bytes = roundtrip_pytree(w, p_s=0.25, p_q=8)
print(f"[compress] dense {pytree_dense_bytes(w)}B -> wire {wire_bytes}B "
      f"({pytree_dense_bytes(w)/wire_bytes:.1f}x)")

# 2. Staleness weighting (Eq. 6) ------------------------------------------
print("[staleness] S(0..4) =",
      [round(float(staleness_weight(s, 0.5)), 3) for s in range(5)])

# 3. The server state machine (Algs. 1-2) ---------------------------------
srv = TeasqServer({"w": jnp.zeros(3)}, ServerConfig(n_devices=20,
                                                    c_fraction=0.1))
print("[server] dispatch granted:", srv.try_dispatch() is not None,
      "| parallel limit:", srv.cfg.max_parallel,
      "| cache size K:", srv.cfg.cache_size)

# 4. A small end-to-end async FL run ---------------------------------------
data, parts, w0 = make_setup(n_devices=10, n_train=2000, n_test=500)
si, qi, _ = profile_compression(w0, data, theta=0.03)     # Algorithm 5
sched = make_schedule(si, qi, total_rounds=30)
hist = run_method("teasq", data, parts, w0, time_budget=40.0,
                  epochs=1, schedule=sched)
best = max(h.accuracy for h in hist)
print(f"[teasq] {hist[-1].round} rounds, acc {hist[0].accuracy:.3f} -> "
      f"{best:.3f}, uploaded {hist[-1].bytes_up//1024}KB")
