#!/usr/bin/env bash
# One entry point for builders and CI.
#
# 1. the pinned tier-1 suite (ROADMAP.md):  python -m pytest -x -q
#    (pytest.ini excludes the opt-in wall-clock `scale` marker)
# 2. the fast smoke subset, which includes the benchmark harness smoke
#    tests (tests/test_codec_throughput.py) — <60 s total
#
# Usage: scripts/tier1.sh [extra pytest args for the tier-1 run]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[tier1] pinned suite: python -m pytest -x -q $*"
python -m pytest -x -q "$@"

echo "[tier1] smoke subset: python -m pytest -m smoke -q"
python -m pytest -m smoke -q
