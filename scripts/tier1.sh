#!/usr/bin/env bash
# One entry point for builders and CI.
#
# 1. the pinned tier-1 suite (ROADMAP.md):  python -m pytest -x -q
#    (pytest.ini excludes the opt-in wall-clock `scale` marker)
# 2. the fast smoke subset: the benchmark harness smoke tests
#    (tests/test_codec_throughput.py), the FLTask registry conformance
#    fast subset (tests/test_tasks.py — per-task loss/grad/cohort/codec
#    checks on tiny configs; the end-to-end runs stay tier-1-only), the
#    batched-scheduler smoke slice (tests/test_batched_engine.py —
#    small batched end-to-end runs on teasq and fedavg plus the
#    EventTable/registry unit checks, so every build exercises BOTH
#    SimConfig.scheduler paths), the vectorized wave-handler smoke
#    slice (tests/test_wave_handlers.py — `-m smoke` end-to-end runs
#    with SimConfig.handler_mode="wave" on teasq/fedasync/fedavg plus
#    the mode-validation checks, so every build exercises both handler
#    modes; the exact wave-vs-heap parity grid, the hypothesis property
#    suite and the serial re-pin stay tier-1-only), and the multi-task
#    fleet smoke slice
#    (tests/test_fleet.py — ASSIGNERS unit checks plus a 4-family
#    heterogeneous shared-fleet run, so every build exercises the
#    repro.fl.fleet layer; the bit-parity and checkpoint/resume tests
#    stay tier-1-only), and the fused-kernel smoke slice (the `-m smoke`
#    marked grids in tests/test_kernels.py and tests/test_fused_pack.py:
#    the fused sparsify+quantize+pack emitter runs as interpret-mode
#    Pallas, so CPU CI executes the exact kernel body that lowers to TPU
#    pallas_call and pins it byte-identical to the host oracle stream;
#    the hypothesis property suite in tests/test_fused_pack_properties.py
#    and the pinned-history fused run stay tier-1-only), the
#    sharded-server smoke slice (tests/test_sharded_server.py — SERVERS
#    registry/validation units plus a small stacked-vs-sharded kernel
#    parity check; the mesh-width subprocess grid, the hypothesis
#    property suite and the degenerate-mesh bit-identity re-pin stay
#    tier-1-only), and the serve smoke slice (tests/test_serve.py —
#    continuous-batcher-vs-solo-generate token parity, mid-flight
#    admission, the checkpoint->serve roundtrip and the
#    benchmarks/serve_bench.py harness smoke; slot recycling, MoE and
#    the fleet-blob bridge stay tier-1-only) — <60 s total
# 3. the docs check: tests/test_docs.py parses the fenced commands in
#    README.md and docs/*.md and verifies every referenced file and flag
#    exists (so the documentation front door cannot silently rot)
#
# Opt-in (NOT run by default — pytest.ini deselects the `scale` marker):
# the wall-clock stress tier, including the 10^6-device wave-mode
# dispatch stress test (tests/test_wave_handlers.py — several minutes
# and a few GB of RAM):
#
#   PYTHONPATH=src python -m pytest -m scale -o addopts="" -q
#
# Usage: scripts/tier1.sh [extra pytest args for the tier-1 run]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[tier1] pinned suite: python -m pytest -x -q $*"
python -m pytest -x -q "$@"

echo "[tier1] smoke subset: python -m pytest -m smoke -q"
python -m pytest -m smoke -q

echo "[tier1] docs check: python -m pytest tests/test_docs.py -m smoke -q"
python -m pytest tests/test_docs.py -m smoke -q
