"""Dump the parity-test LogEntry histories to a JSON fixture.

Run this on a KNOWN-GOOD revision to (re)generate
tests/data/pinned_histories.json, which tests/test_engine_parity.py then
compares against bit-for-bit.  The fixture pins the default
``SimConfig(task="fmnist_cnn")`` path across refactors: a change that
perturbs RNG draw order, byte accounting, or aggregation numerics on the
default path shows up as a fixture mismatch even if engine and legacy
backends drift together.

  PYTHONPATH=src python scripts/dump_pinned_histories.py
"""
import dataclasses
import json
import os

from repro.fl.protocols import make_setup, run_method

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                   "pinned_histories.json")

# The fixture records its own generation config: the parity test replays
# exactly what the file says (and cross-checks it against its module
# fixture), so the script and the test cannot drift apart silently.
SETUP = dict(n_devices=8, iid=True, seed=3, n_train=640, n_test=320)
RUN_KW = dict(time_budget=4.0, epochs=1, seed=3)
RUNS = {
    "teasq": dict(p_s=0.25, p_q=8),
    "fedasync": {},
    "fedavg": dict(devices_per_round=3),
}

# The batched-scheduler fixtures (tests/test_batched_engine.py): the same
# tiny workload run through SimConfig.scheduler="batched", including one
# cohort-trainer config so the deferred path is pinned too.  The parity
# test replays each under BOTH schedulers, so these fixtures also pin the
# heap path onto the batched histories.
RUNS_BATCHED = {
    "teasq": dict(p_s=0.25, p_q=8, cohort_size=4, scheduler="batched"),
    "fedasync": dict(scheduler="batched"),
    "fedavg": dict(devices_per_round=3, scheduler="batched"),
}


def _dump(data, parts, w0, runs, tag):
    hists = {}
    for method, kw in runs.items():
        hist = run_method(method, data, parts, w0, backend="engine",
                          **RUN_KW, **kw)
        hists[method] = [dataclasses.asdict(h) for h in hist]
        print(f"{tag}/{method}: {len(hist)} entries, "
              f"last round {hist[-1].round}")
    return hists


def main():
    data, parts, w0 = make_setup(**SETUP)
    hists = _dump(data, parts, w0, RUNS, "heap")
    hists_batched = _dump(data, parts, w0, RUNS_BATCHED, "batched")
    os.makedirs(os.path.dirname(os.path.abspath(OUT)), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"setup": SETUP, "run_kw": RUN_KW, "runs": RUNS,
                   "histories": hists, "runs_batched": RUNS_BATCHED,
                   "histories_batched": hists_batched}, f, indent=1)
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
