"""Dump the parity-test LogEntry histories to a JSON fixture.

Run this on a KNOWN-GOOD revision to (re)generate
tests/data/pinned_histories.json, which tests/test_engine_parity.py then
compares against bit-for-bit.  The fixture pins the default
``SimConfig(task="fmnist_cnn")`` path across refactors: a change that
perturbs RNG draw order, byte accounting, or aggregation numerics on the
default path shows up as a fixture mismatch even if engine and legacy
backends drift together.

  PYTHONPATH=src python scripts/dump_pinned_histories.py
"""
import dataclasses
import json
import os

from repro.fl.protocols import make_setup, run_method

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                   "pinned_histories.json")

# The fixture records its own generation config: the parity test replays
# exactly what the file says (and cross-checks it against its module
# fixture), so the script and the test cannot drift apart silently.
SETUP = dict(n_devices=8, iid=True, seed=3, n_train=640, n_test=320)
RUN_KW = dict(time_budget=4.0, epochs=1, seed=3)
RUNS = {
    "teasq": dict(p_s=0.25, p_q=8),
    "fedasync": {},
    "fedavg": dict(devices_per_round=3),
}

# The batched-scheduler fixtures (tests/test_batched_engine.py): the same
# tiny workload run through SimConfig.scheduler="batched", including one
# cohort-trainer config so the deferred path is pinned too.  The parity
# test replays each under BOTH schedulers, so these fixtures also pin the
# heap path onto the batched histories.
RUNS_BATCHED = {
    "teasq": dict(p_s=0.25, p_q=8, cohort_size=4, scheduler="batched"),
    "fedasync": dict(scheduler="batched"),
    "fedavg": dict(devices_per_round=3, scheduler="batched"),
}

# The single-task-fleet fixtures (tests/test_fleet.py): the same tiny
# workload driven through repro.fl.fleet.MultiTaskEngine as a degenerate
# one-task fleet, on both schedulers.  By construction these must be
# bit-identical to the engine histories above (same configs), which the
# fleet test asserts — so the fleet loop is pinned both against the
# known-good revision AND onto the engine fixtures.
RUNS_FLEET = {
    "teasq": dict(p_s=0.25, p_q=8),
    "fedasync": {},
}


def _dump_fleet(data, parts, w0):
    from repro.fl.fleet import FleetConfig, MultiTaskEngine
    from repro.fl.simulator import SimConfig
    out = {}
    for scheduler in ("heap", "batched"):
        hists = {}
        for method, kw in RUNS_FLEET.items():
            run = {**RUN_KW, **kw}
            time_budget = run.pop("time_budget")
            # mirror run_method's SimConfig defaults (see run_tiny_fleet in
            # tests/test_fleet.py, which replays this fixture)
            spec = SimConfig(method=method, n_devices=SETUP["n_devices"],
                             c_fraction=0.1, mu=0.01, alpha=0.6,
                             p_s=run.pop("p_s", 0.25),
                             p_q=run.pop("p_q", 8), **run)
            fleet = MultiTaskEngine([data], [parts], [w0], FleetConfig(
                tasks=[spec], n_devices=SETUP["n_devices"], seed=spec.seed,
                scheduler=scheduler))
            hist = fleet.run(time_budget=time_budget)[0]
            hists[method] = [dataclasses.asdict(h) for h in hist]
            print(f"fleet/{scheduler}/{method}: {len(hist)} entries, "
                  f"last round {hist[-1].round}")
        out[scheduler] = hists
    return out


def _dump(data, parts, w0, runs, tag):
    hists = {}
    for method, kw in runs.items():
        hist = run_method(method, data, parts, w0, backend="engine",
                          **RUN_KW, **kw)
        hists[method] = [dataclasses.asdict(h) for h in hist]
        print(f"{tag}/{method}: {len(hist)} entries, "
              f"last round {hist[-1].round}")
    return hists


def main():
    data, parts, w0 = make_setup(**SETUP)
    hists = _dump(data, parts, w0, RUNS, "heap")
    hists_batched = _dump(data, parts, w0, RUNS_BATCHED, "batched")
    hists_fleet = _dump_fleet(data, parts, w0)
    os.makedirs(os.path.dirname(os.path.abspath(OUT)), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"setup": SETUP, "run_kw": RUN_KW, "runs": RUNS,
                   "histories": hists, "runs_batched": RUNS_BATCHED,
                   "histories_batched": hists_batched,
                   "runs_fleet": RUNS_FLEET,
                   "histories_fleet": hists_fleet}, f, indent=1)
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
