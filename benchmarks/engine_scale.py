"""Engine scale demo: 1000-device TEASQ via the vectorized cohort path vs
the legacy per-device Python loop at 100 devices, same dataset and virtual
30 s budget — for any registered model family (``--task``).

The comparison partitions one fixed dataset either 100 or 1000 ways (so
total sample throughput per virtual second is comparable), runs TEASQ
(p_s=0.25, p_q=8) under a 200 kHz cell, and reports wall-clock, completed
tasks, and aggregation rounds.  The vectorized run executes many times the
protocol tasks of the legacy run; the acceptance bar is that it still
finishes in less wall-clock.  Results merge into
results/engine_scale.json keyed per task, so the perf trajectory covers
multiple model families side by side.

``--tiered`` switches to the tier-aware codec-policy demo: a heterogeneous
three-tier fleet where the ``tier_aware`` policy gives slow-bandwidth tiers
aggressively packed updates while full-rate tiers stay near-dense; per-tier
uplink totals are metered exactly and logged under the task's
``tier_aware`` key.

``--fleet`` runs the multi-task fleet acceptance demo
(``repro.fl.fleet.MultiTaskEngine``): four heterogeneous FL jobs —
fmnist_cnn/teasq, transformer_lm/teastatic, moe_lm/fedasync,
ssm_lm/teasq — co-training over ONE shared 10^4-device fleet under the
batched scheduler, once with the statically partitioned ``weighted``
assigner and once with the FedAST-style ``adaptive`` one, same virtual
budget.  Logs per-task completions, rounds, ms_per_task and wire bytes
under the top-level ``fleet`` key; the acceptance bar is the adaptive
assigner completing >= 1.2x the aggregate protocol tasks of the static
partition (it reallocates grant probability toward jobs with free
admission slots / slower-converging loss curves, so capacity a small
C-fraction gate strands is immediately reused).

``--scheduler batched`` switches the engine's event loop to
``repro.fl.engine.BatchedEngine`` (resident per-device event arrays,
vectorized next-K selection — bit-identical histories, see
tests/test_batched_engine.py) and runs it solo: at 10^4-10^5 devices the
quantity of interest is the per-task dispatch cost (``ms_per_task``), logged
under the task's ``batched`` key, against the heap rows already in the
results file.  ``--host-tuning`` re-execs with the olmax-style host setup
(tcmalloc LD_PRELOAD when present, optional
``--xla_force_host_platform_device_count`` via ``--host-devices``).

  PYTHONPATH=src python -m benchmarks.engine_scale [--budget 30] [--devices 1000]
  PYTHONPATH=src python -m benchmarks.engine_scale --task transformer_lm
  PYTHONPATH=src python -m benchmarks.engine_scale --tiered --devices 120 --samples 6000 --budget 6
  PYTHONPATH=src python -m benchmarks.engine_scale --scheduler batched \\
      --devices 100000 --samples 100000 --cohort 256 --budget 8 --host-tuning
  PYTHONPATH=src python -m benchmarks.engine_scale --fleet --devices 10000 --budget 3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

from benchmarks.common import (host_tuning_active, maybe_reexec_host_tuned,
                               profiled)

import jax

from repro.core.latency import WirelessConfig
from repro.data.synthetic import partition_iid
from repro.fl.protocols import make_sim
from repro.fl.simulator import ScenarioConfig, SimConfig, TierSpec
from repro.fl.tasks import TASKS, get_task

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "engine_scale.json")


def scale_config(n_devices: int, *, batch_size: int = 8, seed: int = 0,
                 cohort_size: int = 0, task: str = "fmnist_cnn",
                 scheduler: str = "heap",
                 handler_mode: str = "serial") -> SimConfig:
    """TEASQ at N devices with a constant K=10 aggregation cache and a
    200 kHz cell (longer rounds keep the demo's virtual-task count sane)."""
    return SimConfig(
        method="teasq", task=task, n_devices=n_devices, c_fraction=0.1,
        gamma=10.0 / n_devices, epochs=1, batch_size=batch_size,
        p_s=0.25, p_q=8, seed=seed,
        wireless=WirelessConfig(bandwidth_hz=2e5),
        cohort_size=cohort_size, cohort_channel_iters=6,
        scheduler=scheduler, handler_mode=handler_mode)


def run_one(data, n_train: int, n_devices: int, backend: str,
            cohort_size: int, budget: float, seed: int = 0,
            task: str = "fmnist_cnn", scheduler: str = "heap",
            handler_mode: str = "serial") -> dict:
    parts = partition_iid(n_train, n_devices, seed)
    w0 = get_task(task).init_params(jax.random.PRNGKey(seed))
    cfg = scale_config(n_devices, seed=seed, cohort_size=cohort_size,
                       task=task, scheduler=scheduler,
                       handler_mode=handler_mode)
    sim = make_sim(data, parts, w0, cfg, backend=backend)
    t0 = time.perf_counter()
    hist = sim.run(time_budget=budget, eval_every=10 ** 9)
    wall = time.perf_counter() - t0
    stats = getattr(sim, "stats", None)
    tasks = stats.completions if stats is not None else None
    return {
        "task": task, "backend": backend, "scheduler": scheduler,
        "handler_mode": handler_mode, "n_devices": n_devices,
        "cohort_size": cohort_size, "wall_s": wall, "budget": budget,
        "rounds": hist[-1].round, "accuracy": hist[-1].accuracy,
        "bytes_up_mb": hist[-1].bytes_up / 1e6,
        "tasks": tasks,
        "ms_per_task": wall * 1e3 / tasks if tasks else None,
        "flushes": stats.flushes if stats is not None else None,
        "host_tuning": host_tuning_active(),
    }


def tier_scenario() -> ScenarioConfig:
    """The demo fleet: a quarter full-rate, the rest on progressively
    slower links/compute — the heterogeneity the tier_aware policy prices
    per device."""
    return ScenarioConfig(tiers=[
        TierSpec(0.25, compute_scale=1.0, bandwidth_scale=1.0, name="fast"),
        TierSpec(0.375, compute_scale=1.5, bandwidth_scale=0.5, name="mid"),
        TierSpec(0.375, compute_scale=2.5, bandwidth_scale=0.125,
                 name="slow"),
    ])


def run_tiered(data, n_train: int, n_devices: int, budget: float,
               seed: int = 0, task: str = "fmnist_cnn") -> dict:
    """Tier-aware codec-policy run: heterogeneous bandwidth tiers, a
    per-device codec from the ``tier_aware`` policy, and per-tier uplink
    metering (``ChannelMeter.tier_up``).  The acceptance property logged
    here: the slowest bandwidth tier's metered uplink bytes are strictly
    below the fastest tier's, both in total and per transfer."""
    parts = partition_iid(n_train, n_devices, seed)
    w0 = get_task(task).init_params(jax.random.PRNGKey(seed))
    cfg = dataclasses.replace(
        scale_config(n_devices, seed=seed, cohort_size=0, task=task),
        scenario=tier_scenario(), codec_policy="tier_aware")
    sim = make_sim(data, parts, w0, cfg, backend="engine")
    t0 = time.perf_counter()
    hist = sim.run(time_budget=budget, eval_every=10 ** 9)
    wall = time.perf_counter() - t0
    per_tier = []
    for i, t in enumerate(cfg.scenario.tiers):
        sel = sim.devices.tier == i
        n_tier = int(sel.sum())
        if n_tier:   # tiny fleets can round a tier down to zero devices
            codec = sim.strategy.channel_for(0, device_id=int(sel.argmax()))
            p_s, p_q = codec.p_s, codec.p_q
            per_upload = codec.wire_bytes(w0)
        else:
            p_s = p_q = per_upload = None
        per_tier.append({
            "tier": t.name, "bandwidth_scale": t.bandwidth_scale,
            "devices": n_tier,
            "p_s": p_s, "p_q": p_q,
            "bytes_per_upload": per_upload,
            "uplink_bytes": sim.channel.tier_up.get(i, 0),
            "downlink_bytes": sim.channel.tier_down.get(i, 0),
            "completions": int(sim.stats.completed_per_device[sel].sum()),
        })
    return {
        "task": task, "n_devices": n_devices, "budget": budget,
        "wall_s": wall, "rounds": hist[-1].round,
        "accuracy": hist[-1].accuracy,
        "bytes_up_mb": hist[-1].bytes_up / 1e6, "per_tier": per_tier,
    }


def fleet_specs(n_devices: int, cohort: int) -> list:
    """The four heterogeneous acceptance jobs.  Every job's Alg. 1 gate
    admits MORE concurrent devices than its static quarter-share (0.25*N)
    can supply — except the SSM job, whose tiny ceil(0.004*N) gate strands
    almost all of its share in the waiting queue.  The static partition
    therefore tops out near 0.754*N busy devices, and the stranding hits
    hardest on the transformer job: its small wire footprint gives it the
    fastest round turnaround (it dominates aggregate completions), and its
    wide 0.5*N gate means it can productively absorb every device the
    other gates cannot hold.  The adaptive assigner routes each freed
    device to whichever job still has an open slot (aggregate gate
    capacity 1.064*N > N), so the slow jobs fill their 0.28*N gates and
    the whole remaining fleet pools in the transformer job — that
    occupancy gap is the >= 1.2x aggregate-tasks acceptance bar."""
    common = dict(n_devices=n_devices, gamma=10.0 / n_devices, epochs=1,
                  batch_size=8, cohort_size=cohort, cohort_channel_iters=6,
                  wireless=WirelessConfig(bandwidth_hz=2e5))
    return [
        SimConfig(method="teasq", task="fmnist_cnn", c_fraction=0.28,
                  p_s=0.25, p_q=8, **common),
        SimConfig(method="teastatic", task="transformer_lm",
                  c_fraction=0.5, p_s=0.25, p_q=8, **common),
        SimConfig(method="fedasync", task="moe_lm", c_fraction=0.28,
                  p_s=1.0, p_q=32, **common),
        SimConfig(method="teasq", task="ssm_lm", c_fraction=0.004,
                  p_s=0.25, p_q=8, **common),
    ]


def run_fleet_once(n_devices: int, budget: float, assigner: str,
                   cohort: int, seed: int = 0) -> dict:
    from repro.fl.fleet import FleetConfig, build_fleet
    cfg = FleetConfig(tasks=fleet_specs(n_devices, cohort),
                      n_devices=n_devices, seed=seed, scheduler="batched",
                      assigner=assigner,
                      wireless=WirelessConfig(bandwidth_hz=2e5))
    # one sample per device per job: local compute stays near zero, so
    # completions measure scheduling/occupancy, not the model families
    fleet = build_fleet(cfg, n_train=n_devices, n_test=200)
    t0 = time.perf_counter()
    hists = fleet.run(time_budget=budget, eval_every=10 ** 9)
    wall = time.perf_counter() - t0
    per_task = []
    for spec, rt, hist in zip(cfg.tasks, fleet.runtimes, hists):
        per_task.append({
            "task": spec.task, "method": spec.method,
            "c_fraction": spec.c_fraction,
            "completions": rt.stats.completions,
            "rounds": hist[-1].round,
            "bytes_up_mb": rt.channel.bytes_up / 1e6,
            "bytes_down_mb": rt.channel.bytes_down / 1e6,
        })
    total = sum(r["completions"] for r in per_task)
    return {"assigner": assigner, "wall_s": wall, "tasks_total": total,
            "ms_per_task": wall * 1e3 / total if total else None,
            "per_task": per_task}


def run(scale) -> list:
    """Suite entry point: full scale = the 30 s acceptance demo; quick scale
    shortens the budget to 10 s (same 1000-vs-100 device comparison)."""
    budget = 30.0 if scale.full else 10.0
    task = get_task("fmnist_cnn")
    data = task.make_data(12000, 1000, 0)
    rows = [run_one(data, 12000, 100, "legacy", 0, budget),
            run_one(data, 12000, 1000, "engine", 32, budget)]
    return rows


def _merge_results(path: str, task: str, entry: dict) -> dict:
    """Keep one entry per task so the CNN acceptance numbers, any other
    family's runs, and the tier-aware policy run live side by side in the
    same results file.  ``entry`` keys merge into the task's existing dict
    (so a scale run does not clobber a logged ``tier_aware`` run and vice
    versa)."""
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    # legacy layout (pre per-task keys) was the CNN run at top level
    if "rows" in out:
        out = {"fmnist_cnn": {k: out[k] for k in ("rows", "speedup", "budget")
                              if k in out}}
    out[task] = {**out.get(task, {}), **entry}
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=30.0)
    ap.add_argument("--devices", type=int, default=1000)
    ap.add_argument("--legacy-devices", type=int, default=100)
    ap.add_argument("--cohort", type=int, default=32)
    ap.add_argument("--samples", type=int, default=12000)
    ap.add_argument("--task", choices=sorted(TASKS), default="fmnist_cnn",
                    help="model family to scale (default: %(default)s)")
    ap.add_argument("--fleet", action="store_true",
                    help="multi-task fleet acceptance demo: 4 heterogeneous "
                         "jobs (CNN + transformer + MoE + SSM) co-training "
                         "on one shared --devices fleet, batched scheduler, "
                         "weighted vs adaptive assigner in the same virtual "
                         "budget (logged under the top-level 'fleet' key)")
    ap.add_argument("--tiered", action="store_true",
                    help="run the tier_aware codec-policy demo instead of "
                         "the scale race: heterogeneous bandwidth tiers, "
                         "per-device codecs, per-tier uplink metering "
                         "(logged under the task's 'tier_aware' key)")
    ap.add_argument("--scheduler", choices=("heap", "batched"),
                    default="heap",
                    help="engine event loop (SimConfig.scheduler); 'batched'"
                         " runs solo and logs ms_per_task under the task's "
                         "'batched' key")
    ap.add_argument("--handler-mode", choices=("serial", "wave"),
                    default="serial",
                    help="batched-scheduler event handling "
                         "(SimConfig.handler_mode): 'serial' is the pinned "
                         "bit-parity path, 'wave' dispatches same-kind "
                         "event runs as vectorized waves (relaxed parity; "
                         "rows are keyed *_wave_n<N>)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each timed run; the top-20 cumulative "
                         "rows land next to results/engine_scale.json")
    ap.add_argument("--host-tuning", action="store_true",
                    help="re-exec with tcmalloc LD_PRELOAD (when installed) "
                         "and optional XLA host-device partitioning before "
                         "jax initializes")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="with --host-tuning: value for "
                         "--xla_force_host_platform_device_count (0 = "
                         "leave XLA_FLAGS untouched)")
    ap.add_argument("--dispatch-bench", action="store_true",
                    help="dispatch-isolated microbenchmark: heap@1000 vs "
                         "batched@--devices on a compute-light TEASQ "
                         "workload (fmnist_mlp, one sample per device = "
                         "zero local minibatches), so ms_per_task measures "
                         "the scheduler, not the model; logs the pair + "
                         "cost ratio under fmnist_mlp's 'dispatch' key")
    args = ap.parse_args()
    maybe_reexec_host_tuned(args.host_tuning, args.host_devices)

    if args.fleet:
        runs = {}
        for assigner in ("weighted", "adaptive"):
            r = run_fleet_once(args.devices, args.budget, assigner,
                               args.cohort)
            runs[assigner] = r
            detail = " ".join(f"{p['task']}={p['completions']}"
                              for p in r["per_task"])
            print(f"engine_scale/fleet/{assigner}_n{args.devices},"
                  f"{r['tasks_total']},"
                  f"wall={r['wall_s']:.1f}s ms_per_task="
                  f"{r['ms_per_task']:.3f} {detail}", flush=True)
        ratio = (runs["adaptive"]["tasks_total"]
                 / max(runs["weighted"]["tasks_total"], 1))
        print(f"engine_scale/fleet/adaptive_vs_weighted,{ratio:.2f},"
              f"aggregate tasks, same {args.budget}s virtual budget",
              flush=True)
        entry = {"n_devices": args.devices, "budget": args.budget,
                 "scheduler": "batched", "cohort_size": args.cohort,
                 "tasks": [s.task for s in
                           fleet_specs(args.devices, args.cohort)],
                 "assigners": runs, "adaptive_vs_weighted_tasks": ratio}
        os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                    exist_ok=True)
        merged = _merge_results(RESULTS_PATH, "fleet", entry)
        with open(RESULTS_PATH, "w") as f:
            json.dump(merged, f, indent=1)
        return

    if args.dispatch_bench:
        # Training and Eqs. 6-10 aggregation are bit-identical work under
        # both schedulers, so an end-to-end ms_per_task at a real model
        # mostly measures the model.  This pair holds per-task protocol
        # compute near zero and varies only (scheduler, N): wall/tasks is
        # then the per-task dispatch cost the ROADMAP item targets.
        task = "fmnist_mlp"
        rows = {}
        if args.handler_mode == "wave":
            # wave rows ride on the serial baselines already in the file;
            # only the batched wave run itself is timed
            runs = [("batched", args.devices, args.budget)]
        else:
            # heap@1000 and batched@N get full budgets; heap@N gets a
            # short one (it exists to price the heap at the same N, not
            # to run long)
            runs = [("heap", 1000, 20.0),
                    ("heap", args.devices, min(args.budget, 0.6)),
                    ("batched", args.devices, args.budget)]
        prof_dir = os.path.dirname(os.path.abspath(RESULTS_PATH))
        for scheduler, n, budget in runs:
            key = (f"batched_wave_n{n}" if args.handler_mode == "wave"
                   else f"{scheduler}_n{n}")
            data = get_task(task).make_data(n, 1000, 0)
            with profiled(args.profile, os.path.join(
                    prof_dir, f"engine_scale_dispatch_{key}.profile.txt")):
                r = run_one(data, n, n, "engine", args.cohort, budget,
                            task=task, scheduler=scheduler,
                            handler_mode=args.handler_mode)
            rows[key] = r
            print(f"engine_scale/{task}/dispatch_{key},"
                  f"{(r['ms_per_task'] or 0) * 1e3:.1f},"
                  f"wall={r['wall_s']:.1f}s tasks={r['tasks']} "
                  f"ms_per_task={r['ms_per_task']:.3f}", flush=True)
        # merge into the existing dispatch dict — a wave run must not
        # clobber the serial baselines (and vice versa)
        prev = {}
        if os.path.exists(RESULTS_PATH):
            with open(RESULTS_PATH) as f:
                prev = json.load(f).get(task, {}).get("dispatch", {})
        dispatch = {**prev, **rows}
        if args.handler_mode == "wave":
            base = dispatch.get(f"batched_n{args.devices}")
            if base and base.get("ms_per_task"):
                ratio = (base["ms_per_task"]
                         / dispatch[f"batched_wave_n{args.devices}"]
                         ["ms_per_task"])
                dispatch[f"wave_vs_serial_n{args.devices}"] = ratio
                print(f"engine_scale/{task}/dispatch_wave_vs_serial,"
                      f"{ratio:.2f},batched serial vs wave @ "
                      f"N={args.devices}")
        else:
            same_n = (rows[f"heap_n{args.devices}"]["ms_per_task"]
                      / rows[f"batched_n{args.devices}"]["ms_per_task"])
            dispatch["same_n_ratio"] = same_n
            print(f"engine_scale/{task}/dispatch_same_n_ratio,"
                  f"{same_n:.2f},heap vs batched @ N={args.devices}")
        os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                    exist_ok=True)
        merged = _merge_results(RESULTS_PATH, task, {"dispatch": dispatch})
        with open(RESULTS_PATH, "w") as f:
            json.dump(merged, f, indent=1)
        return

    data = get_task(args.task).make_data(args.samples, 1000, 0)

    if args.scheduler == "batched" and not args.tiered:
        # solo batched run: the heap rows in the results file are the
        # baseline; re-running the legacy loop at 10^5 devices would take
        # hours for a number the file already has
        key = ("batched_wave" if args.handler_mode == "wave"
               else "batched")
        prof = os.path.join(
            os.path.dirname(os.path.abspath(RESULTS_PATH)),
            f"engine_scale_{args.task}_{key}.profile.txt")
        with profiled(args.profile, prof):
            r = run_one(data, args.samples, args.devices, "engine",
                        args.cohort, args.budget, task=args.task,
                        scheduler="batched",
                        handler_mode=args.handler_mode)
        ms = r["ms_per_task"] or float("nan")
        print(f"engine_scale/{args.task}/{key}_n{args.devices},"
              f"{ms * 1e3:.1f},"
              f"wall={r['wall_s']:.1f}s tasks={r['tasks']} "
              f"rounds={r['rounds']} ms_per_task={ms:.3f}", flush=True)
        os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                    exist_ok=True)
        merged = _merge_results(RESULTS_PATH, args.task, {key: r})
        with open(RESULTS_PATH, "w") as f:
            json.dump(merged, f, indent=1)
        return

    if args.tiered:
        r = run_tiered(data, args.samples, args.devices, args.budget,
                       task=args.task)
        for row in r["per_tier"]:
            print(f"engine_scale/{args.task}/tier_{row['tier']},"
                  f"{row['uplink_bytes']},"
                  f"bw={row['bandwidth_scale']} point=({row['p_s']},"
                  f"{row['p_q']}) per_upload={row['bytes_per_upload']}B "
                  f"completions={row['completions']}", flush=True)
        os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                    exist_ok=True)
        merged = _merge_results(RESULTS_PATH, args.task, {"tier_aware": r})
        with open(RESULTS_PATH, "w") as f:
            json.dump(merged, f, indent=1)
        return
    rows = []
    for name, n, backend, cohort in [
            ("legacy", args.legacy_devices, "legacy", 0),
            ("engine_cohort", args.devices, "engine", args.cohort)]:
        r = run_one(data, args.samples, n, backend, cohort, args.budget,
                    task=args.task)
        rows.append(r)
        print(f"engine_scale/{args.task}/{name}_n{n},"
              f"{r['wall_s'] * 1e6 / max(r['rounds'], 1):.1f},"
              f"wall={r['wall_s']:.1f}s rounds={r['rounds']} "
              f"tasks={r['tasks']} acc={r['accuracy']:.3f}", flush=True)

    speedup = rows[0]["wall_s"] / rows[1]["wall_s"]
    print(f"engine_scale/{args.task}/speedup,{speedup:.2f},"
          f"vec@{args.devices} vs legacy@{args.legacy_devices}")
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)), exist_ok=True)
    merged = _merge_results(RESULTS_PATH, args.task,
                            {"rows": rows, "speedup": speedup,
                             "budget": args.budget})
    with open(RESULTS_PATH, "w") as f:
        json.dump(merged, f, indent=1)


if __name__ == "__main__":
    main()
