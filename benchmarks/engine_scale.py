"""Engine scale demo: 1000-device TEASQ via the vectorized cohort path vs
the legacy per-device Python loop at 100 devices, same dataset and virtual
30 s budget — for any registered model family (``--task``).

The comparison partitions one fixed dataset either 100 or 1000 ways (so
total sample throughput per virtual second is comparable), runs TEASQ
(p_s=0.25, p_q=8) under a 200 kHz cell, and reports wall-clock, completed
tasks, and aggregation rounds.  The vectorized run executes many times the
protocol tasks of the legacy run; the acceptance bar is that it still
finishes in less wall-clock.  Results merge into
results/engine_scale.json keyed per task, so the perf trajectory covers
multiple model families side by side.

  PYTHONPATH=src python -m benchmarks.engine_scale [--budget 30] [--devices 1000]
  PYTHONPATH=src python -m benchmarks.engine_scale --task transformer_lm
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core.latency import WirelessConfig
from repro.data.synthetic import partition_iid
from repro.fl.protocols import make_sim
from repro.fl.simulator import SimConfig
from repro.fl.tasks import TASKS, get_task

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "engine_scale.json")


def scale_config(n_devices: int, *, batch_size: int = 8, seed: int = 0,
                 cohort_size: int = 0, task: str = "fmnist_cnn") -> SimConfig:
    """TEASQ at N devices with a constant K=10 aggregation cache and a
    200 kHz cell (longer rounds keep the demo's virtual-task count sane)."""
    return SimConfig(
        method="teasq", task=task, n_devices=n_devices, c_fraction=0.1,
        gamma=10.0 / n_devices, epochs=1, batch_size=batch_size,
        p_s=0.25, p_q=8, seed=seed,
        wireless=WirelessConfig(bandwidth_hz=2e5),
        cohort_size=cohort_size, cohort_channel_iters=6)


def run_one(data, n_train: int, n_devices: int, backend: str,
            cohort_size: int, budget: float, seed: int = 0,
            task: str = "fmnist_cnn") -> dict:
    parts = partition_iid(n_train, n_devices, seed)
    w0 = get_task(task).init_params(jax.random.PRNGKey(seed))
    cfg = scale_config(n_devices, seed=seed, cohort_size=cohort_size,
                       task=task)
    sim = make_sim(data, parts, w0, cfg, backend=backend)
    t0 = time.perf_counter()
    hist = sim.run(time_budget=budget, eval_every=10 ** 9)
    wall = time.perf_counter() - t0
    stats = getattr(sim, "stats", None)
    return {
        "task": task, "backend": backend, "n_devices": n_devices,
        "cohort_size": cohort_size, "wall_s": wall, "budget": budget,
        "rounds": hist[-1].round, "accuracy": hist[-1].accuracy,
        "bytes_up_mb": hist[-1].bytes_up / 1e6,
        "tasks": stats.completions if stats is not None else None,
        "flushes": stats.flushes if stats is not None else None,
    }


def run(scale) -> list:
    """Suite entry point: full scale = the 30 s acceptance demo; quick scale
    shortens the budget to 10 s (same 1000-vs-100 device comparison)."""
    budget = 30.0 if scale.full else 10.0
    task = get_task("fmnist_cnn")
    data = task.make_data(12000, 1000, 0)
    rows = [run_one(data, 12000, 100, "legacy", 0, budget),
            run_one(data, 12000, 1000, "engine", 32, budget)]
    return rows


def _merge_results(path: str, task: str, entry: dict) -> dict:
    """Keep one entry per task so the CNN acceptance numbers and any other
    family's runs live side by side in the same results file."""
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    # legacy layout (pre per-task keys) was the CNN run at top level
    if "rows" in out:
        out = {"fmnist_cnn": {k: out[k] for k in ("rows", "speedup", "budget")
                              if k in out}}
    out[task] = entry
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=30.0)
    ap.add_argument("--devices", type=int, default=1000)
    ap.add_argument("--legacy-devices", type=int, default=100)
    ap.add_argument("--cohort", type=int, default=32)
    ap.add_argument("--samples", type=int, default=12000)
    ap.add_argument("--task", choices=sorted(TASKS), default="fmnist_cnn",
                    help="model family to scale (default: %(default)s)")
    args = ap.parse_args()

    data = get_task(args.task).make_data(args.samples, 1000, 0)
    rows = []
    for name, n, backend, cohort in [
            ("legacy", args.legacy_devices, "legacy", 0),
            ("engine_cohort", args.devices, "engine", args.cohort)]:
        r = run_one(data, args.samples, n, backend, cohort, args.budget,
                    task=args.task)
        rows.append(r)
        print(f"engine_scale/{args.task}/{name}_n{n},"
              f"{r['wall_s'] * 1e6 / max(r['rounds'], 1):.1f},"
              f"wall={r['wall_s']:.1f}s rounds={r['rounds']} "
              f"tasks={r['tasks']} acc={r['accuracy']:.3f}", flush=True)

    speedup = rows[0]["wall_s"] / rows[1]["wall_s"]
    print(f"engine_scale/{args.task}/speedup,{speedup:.2f},"
          f"vec@{args.devices} vs legacy@{args.legacy_devices}")
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)), exist_ok=True)
    merged = _merge_results(RESULTS_PATH, args.task,
                            {"rows": rows, "speedup": speedup,
                             "budget": args.budget})
    with open(RESULTS_PATH, "w") as f:
        json.dump(merged, f, indent=1)


if __name__ == "__main__":
    main()
