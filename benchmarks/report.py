"""Render EXPERIMENTS.md from the result JSONs (dry-run, roofline,
hillclimb, paper benchmarks).  Idempotent: re-run after any experiment.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks import roofline as RL

RESULTS = "results"
OUT = "EXPERIMENTS.md"


def _load(name):
    p = os.path.join(RESULTS, name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def section_dryrun(single, multi) -> str:
    out = ["## §Dry-run\n"]
    out.append(
        "Every (architecture x input shape) pair lowers AND compiles on both "
        "production meshes — `(16,16)=(data,model)` 256 chips and "
        "`(2,16,16)=(pod,data,model)` 512 chips — via "
        "`repro.launch.dryrun` (512 virtual host devices, ShapeDtypeStruct "
        "inputs, no allocation). train_4k lowers the TEASQ-Fed round "
        "(fed_step, gather_q int8 exchange, E=1 local step); prefill lowers "
        "serve prefill (last logits + KV cache out); decode shapes lower "
        "one-token serve steps (long_500k uses a rolling 8192-window cache "
        "for attention archs, native O(1) state for SSM).\n")
    for mesh_name, rows in (("16x16 (256 chips)", single),
                            ("2x16x16 (512 chips)", multi)):
        if not rows:
            out.append(f"**{mesh_name}: MISSING**\n")
            continue
        ok = [r for r in rows if "error" not in r]
        out.append(f"\n### Mesh {mesh_name}: {len(ok)}/{len(rows)} compile\n")
        out.append("| arch | shape | step | params | compile s | "
                   "flops/dev (trip-aware) | HLO bytes/dev | coll bytes/dev | "
                   "temp mem |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(ok, key=lambda x: (x["arch"], x["shape"])):
            cost = r.get("cost", {})
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('step')} "
                f"| {r['params']/1e9:.2f}B | {r.get('compile_s', 0):.0f} "
                f"| {cost.get('flops_trip_aware', cost.get('flops', 0)):.2e} "
                f"| {cost.get('bytes_trip_aware', 0):.2e} "
                f"| {r.get('collectives', {}).get('total', 0):.2e} "
                f"| {_fmt_bytes(r.get('memory', {}).get('temp_size_in_bytes'))} |")
        out.append("")
    return "\n".join(out)


def section_roofline(single) -> str:
    out = ["## §Roofline\n"]
    out.append(
        "Three terms per (arch x shape), single-pod mesh (256 chips), "
        "TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link "
        "ICI.\n\n"
        "* compute = trip-aware HLO dot FLOPs/dev / peak\n"
        "* memory = trip-aware HLO byte traffic/dev / HBM bw (upper bound: "
        "counts every non-fused instruction's operands)\n"
        "* collective = trip-aware per-device link bytes / ICI bw (ring "
        "estimates; all-reduce counted 2x)\n\n"
        "`6ND/HLO` = MODEL_FLOPS (6·N_active·D train / 2·N_active·D decode) "
        "over total compiled FLOPs — <1 means remat/dispatch overhead "
        "(expected ~0.7 with per-layer remat ≈ 4/3 recompute + attention "
        "FLOPs not in 6ND), >1 flags undercounting.\n")
    rows = []
    for rec in single or []:
        if "error" in rec:
            continue
        row = RL.analyze(rec, 256)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | 6ND/HLO | what would move it |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {RL.advice(r)} |")
    out.append("")
    return "\n".join(out)


def section_hillclimb(hc) -> str:
    out = ["### Hillclimb measurements (results/perf/hillclimb.json)\n"]
    if not hc:
        out.append("(run `python -m benchmarks.hillclimb --pair A|B|C`)\n")
        return "\n".join(out)
    out.append("| pair | variant | flops/dev | HLO bytes/dev | coll B/dev | "
               "temp mem | compute s | memory s | collective s |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in hc:
        cost = r.get("cost", {})
        f = cost.get("flops_trip_aware", 0)
        b = cost.get("bytes_trip_aware", 0)
        c = r.get("collectives", {}).get("total", 0)
        out.append(
            f"| {r['arch']}/{r['shape']} | {r.get('variant', '')} "
            f"| {f:.2e} | {b:.2e} | {c:.2e} "
            f"| {_fmt_bytes(r.get('memory', {}).get('temp_size_in_bytes'))} "
            f"| {f/197e12:.2e} | {b/819e9:.2e} | {c/50e9:.2e} |")
    out.append("")
    return "\n".join(out)


PAPER_CLAIMS = """
### Validation against the paper's own claims

| paper claim | our measurement | verdict |
|---|---|---|
| TEA-Fed completes more rounds than FedAvg in equal time (async, no straggler wait; Figs. 3-5) | TEA ~2-4x FedAvg's aggregation rounds per simulated second at N=100, C=0.1 (fig3_5 histories; also asserted in tests/test_system.py) | reproduced |
| C has an optimum (C=0.1 at N=100; too small starves, too large stales; Fig. 3) | accuracy at C in {0.05, 0.1, 0.3} is non-monotone with interior optimum (fig3_5 table) | reproduced (optimum shifts with N, as expected) |
| mu > 0 stabilizes non-IID training (Fig. 2) | small positive mu (0.01) best or tied on non-IID; mu=0.1 over-regularizes | reproduced qualitatively |
| alpha robust in 0.4-0.9 (Fig. 6) | alpha=0.6/0.9 close; alpha=0.2 visibly slower at quick scale (damping x rounds trade-off is budget-dependent) | partially reproduced |
| compression cuts wire size ~44-80% at mild accuracy cost (Table 7, Fig. 8) | Alg.-5-searched static point (p_s=0.5, p_q=4) -> max upload 806KB -> ~170KB (79% cut); packed sparse+quant format matches Table 7 accounting | reproduced |
| dynamic decay (TEASQ) beats static compression late while keeping early speed (Fig. 7, Tables 3-6) | decay schedule converges toward uncompressed late; early phase trades accuracy for wire exactly as Fig. 7 shows; at quick budgets the crossover is budget-limited | reproduced qualitatively |
| staleness weighting: staler updates matter less (Eqs. 6-10) | unit-tested exactly (tests/test_staleness.py); fed_step alpha_t falls from 0.60 to 0.20 as staleness goes 0->8 | reproduced exactly |
| up to ~2x faster time-to-accuracy vs FedAvg (non-IID) | TEA/TEASQ reach FedAvg's mid-range accuracy in fewer simulated seconds in the non-IID runs (table3_6 histories) | reproduced directionally |

Caveats: Fashion-MNIST is not available offline — a calibrated synthetic
10-class dataset of identical shape/cardinality is used (nearest-class-mean
~45%, CNN needs several epochs: matched to FMNIST's learning profile), so
absolute accuracies are not comparable to the paper's; every claim above is
a relative statement on identical data, which the substitution preserves.
The quick-scale wall-time budget compresses the paper's 300-600s windows to
45-90s, which shifts crossover points; `--full` restores the paper's scale.
"""


def section_paper(bench) -> str:
    out = ["## §Paper-claims (FL protocol validation)\n", PAPER_CLAIMS]
    if not bench:
        out.append("(run `python -m benchmarks.run`)\n")
        return "\n".join(out)
    out.append(
        "Synthetic Fashion-MNIST-like data (offline container; relative "
        "comparisons preserved — see DESIGN.md §1). Quick scale = 100 "
        "devices / 12k samples (120/device) / 45s(IID)-90s(non-IID) "
        "budgets unless noted.\n")

    def final_acc(r):
        return max(h[2] for h in r["history"])

    for table, rows in bench.items():
        out.append(f"\n### {table}")
        out.append("| method | dist | rounds | best acc | upload | "
                   "max model up |")
        out.append("|---|---|---|---|---|---|")
        for r in rows:
            h = r["history"][-1]
            out.append(
                f"| {r['method']}{('+' + str(r['kw'])) if r.get('kw') else ''} "
                f"| {'IID' if r['iid'] else 'non-IID'} | {h[1]} "
                f"| {final_acc(r):.3f} | {_fmt_bytes(h[3])} "
                f"| {_fmt_bytes(h[5])} |")
    out.append("")
    return "\n".join(out)


HEADER = """# EXPERIMENTS — TEASQ-Fed reproduction

Generated by `python -m benchmarks.report` from results/*.json.
DESIGN.md documents the system; this file records what was run and measured.

"""


def main() -> None:
    single = _load("dryrun_single.json")
    multi = _load("dryrun_multipod.json")
    hc = _load("perf/hillclimb.json")
    bench = _load("paper_bench.json")

    parts = [HEADER,
             section_dryrun(single, multi),
             section_roofline(single)]

    perf_md = "results/perf/PERF_LOG.md"
    parts.append("## §Perf — hypothesis → change → measure log\n")
    if os.path.exists(perf_md):
        parts.append(open(perf_md).read())
    parts.append(section_hillclimb(hc))
    parts.append(section_paper(bench))

    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
