"""Figs. 3-5: effect of the C-fraction (accuracy vs time and vs rounds,
time-to-target), IID and non-IID, vs FedAvg / FedAsync baselines."""
from benchmarks.common import (Scale, print_csv, record,
                               scale_from_args, simulate, std_argparser)

CS = [0.05, 0.1, 0.3]


def run(scale: Scale):
    rows = []
    for iid in (True, False):
        for c in CS:
            r = simulate(scale, "tea", iid=iid, c_fraction=c)
            r["kw"]["c_fraction"] = c
            rows.append(r)
        rows.append(simulate(scale, "fedavg", iid=iid))
        rows.append(simulate(scale, "fedasync", iid=iid))
    record("fig3_5_c_fraction", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    print_csv("fig3_5_c", run(scale_from_args(args)))


if __name__ == "__main__":
    main()
