"""Fig. 2: effect of the prox regularization weight mu (non-IID)."""
from benchmarks.common import (Scale, print_csv, record,
                               scale_from_args, simulate, std_argparser)

MUS = [0.0, 0.01, 0.1]


def run(scale: Scale):
    rows = []
    for mu in MUS:
        r = simulate(scale, "tea", iid=False, mu=mu)
        r["kw"]["mu"] = mu
        rows.append(r)
    record("fig2_mu", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    print_csv("fig2_mu", run(scale_from_args(args)))


if __name__ == "__main__":
    main()
