"""Shared benchmark infrastructure.

Every benchmark module maps to one paper table/figure, runs the protocol
simulator on the synthetic Fashion-MNIST-like dataset, and prints CSV rows
``name,us_per_call,derived`` where ``us_per_call`` is wall microseconds per
simulated aggregation round and ``derived`` carries the figure's headline
quantity (accuracy / time-to-target / bytes).

Scale: ``--full`` reproduces the paper's setting (100 devices, 60k samples);
the default quick scale (40 devices, 12k samples) preserves every relative
comparison at ~10x less wall time.  Results also land in
results/paper_bench.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import contextlib
import cProfile
import functools
import io
import json
import os
import pstats
import sys
import time
from typing import Dict, List, Optional

from repro.core.dynamic import make_schedule
from repro.fl.protocols import (best_acc_within, make_setup,
                                profile_compression, run_method, time_to_acc,
                                train_global)

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "paper_bench.json")

# the standard Linux locations of gperftools' malloc (the olmax/HomebrewNLP
# JAX training scripts LD_PRELOAD it for large-N host workloads); absent
# libraries are skipped, the tuning degrades gracefully
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)
_HOST_TUNED_MARKER = "_REPRO_HOST_TUNED"


def maybe_reexec_host_tuned(enable: bool, host_devices: int = 0) -> bool:
    """Re-exec the current process with olmax-style host tuning applied:
    ``LD_PRELOAD`` tcmalloc (a loader setting — it cannot be enabled from
    inside a running process, hence the ``os.execve``) and, when
    ``host_devices > 0``, ``XLA_FLAGS=--xla_force_host_platform_device_count``
    so XLA partitions the host CPU into that many logical devices (must be
    set before jax initializes — the re-exec'd process imports jax fresh).

    Call this as early as possible in a benchmark ``main()``.  Returns
    ``False`` when tuning is disabled or already applied (the re-exec'd
    process carries the ``_REPRO_HOST_TUNED`` marker, which both prevents an
    exec loop and tells the benchmark the run is host-tuned); on success the
    call does not return at all."""
    if os.environ.get(_HOST_TUNED_MARKER):
        return False
    if not enable:
        return False
    env = dict(os.environ, **{_HOST_TUNED_MARKER: "1"})
    for path in TCMALLOC_PATHS:
        if os.path.exists(path):
            env["LD_PRELOAD"] = path
            # silence tcmalloc's large-alloc warnings for big numpy buffers
            env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
            break
    if host_devices > 0:
        flag = f"--xla_force_host_platform_device_count={host_devices}"
        env["XLA_FLAGS"] = " ".join(
            x for x in (flag, os.environ.get("XLA_FLAGS", "")) if x)
    # sys.orig_argv keeps the real command line (incl. `-m benchmarks.x`)
    argv = list(getattr(sys, "orig_argv", None)
                or [sys.executable] + sys.argv)
    os.execve(sys.executable, argv, env)
    return True   # unreachable; keeps the signature honest for linters


def host_tuning_active() -> bool:
    """True inside a process re-exec'd by :func:`maybe_reexec_host_tuned`."""
    return bool(os.environ.get(_HOST_TUNED_MARKER))


@contextlib.contextmanager
def profiled(enable: bool, out_path: str, top: int = 20):
    """cProfile the with-block when ``enable`` is set and dump the top-
    ``top`` cumulative rows (plus the same slice re-sorted by total self
    time) as a pstats text report at ``out_path`` — benchmarks pass a path
    next to their results JSON so the profile that explains a recorded
    number travels with it.  Disabled, the context is free, so call sites
    can wrap their timed region unconditionally.  Note the profiled region
    itself runs ~1.3-2x slower under cProfile's tracing; profile runs are
    for attribution, not for the recorded ms_per_task."""
    if not enable:
        yield None
        return
    prof = cProfile.Profile()
    prof.enable()
    try:
        yield prof
    finally:
        prof.disable()
        buf = io.StringIO()
        stats = pstats.Stats(prof, stream=buf)
        stats.sort_stats("cumulative").print_stats(top)
        stats.sort_stats("tottime").print_stats(top)
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            f.write(buf.getvalue())
        print(f"profile: top-{top} rows (cumulative + tottime) -> "
              f"{out_path}", flush=True)


class Scale:
    def __init__(self, full: bool = False, backend: str = "engine",
                 cohort_size: int = 0):
        self.full = full
        # which simulator runs the protocol: the strategy-based engine
        # (default) or the legacy monolithic FLSimulator; cohort_size > 0
        # additionally switches the engine to vectorized cohort training
        self.backend = backend
        self.cohort_size = cohort_size
        # keep the paper's N=100 devices even at quick scale — the
        # C-fraction/cache dynamics (10 parallel, K=10) depend on it;
        # quick mode shrinks per-device data instead (120 samples/device)
        self.n_devices = 100
        self.n_train = 60000 if full else 12000
        self.n_test = 10000 if full else 2500
        self.budget = 300.0 if full else 45.0
        # non-IID learning is ~2x slower (paper: 600s vs 300s budgets)
        self.budget_noniid = 600.0 if full else 90.0
        self.eval_every = 2 if full else 6
        self.epochs = 2 if full else 3

    def budget_for(self, iid: bool) -> float:
        return self.budget if iid else self.budget_noniid


@functools.lru_cache(maxsize=4)
def cached_setup(n_devices: int, iid: bool, n_train: int, n_test: int,
                 seed: int = 0):
    return make_setup(n_devices=n_devices, iid=iid, seed=seed,
                      n_train=n_train, n_test=n_test)


def simulate(scale: Scale, method: str, iid: bool = True, seed: int = 0,
             **kw) -> Dict:
    data, parts, w0 = cached_setup(scale.n_devices, iid, scale.n_train,
                                   scale.n_test, seed)
    t0 = time.time()
    hist = run_method(method, data, parts, w0, iid=iid,
                      time_budget=kw.pop("time_budget", scale.budget_for(iid)),
                      eval_every=kw.pop("eval_every", scale.eval_every),
                      epochs=kw.pop("epochs", scale.epochs), seed=seed,
                      backend=scale.backend,
                      cohort_size=kw.pop("cohort_size", scale.cohort_size),
                      **kw)
    wall = time.time() - t0
    rounds = max(hist[-1].round, 1)
    return {
        "method": method, "iid": iid, "kw": {k: str(v) for k, v in kw.items()},
        "wall_s": wall, "rounds": rounds,
        "us_per_round": wall / rounds * 1e6,
        "history": [[h.time, h.round, h.accuracy, h.bytes_up, h.bytes_down,
                     h.max_model_bytes_up, h.max_model_bytes_down]
                    for h in hist],
    }


_POINTS_CACHE = {}


def compression_points(scale: Scale, iid: bool = True, theta: float = 0.02,
                       total_rounds: int = 60):
    """Algorithm 5 end-to-end: brief training -> greedy search -> decay
    schedule.  Returns {"static": (p_s, p_q), "schedule": ...} — the static
    point is what TEAStatic/TEAS/TEAQ use (the paper derives them the same
    way)."""
    key = (scale.full, iid, theta)
    if key in _POINTS_CACHE:
        return _POINTS_CACHE[key]
    from repro.core.dynamic import DEFAULT_SET_Q, DEFAULT_SET_S
    data, parts, w0 = cached_setup(scale.n_devices, iid, scale.n_train,
                                   scale.n_test)
    # profile on a briefly-TRAINED model (Alg. 5 uses a trained model;
    # a random init is insensitive to compression and the greedy search
    # would pick maximum compression)
    w_warm = train_global(data, parts, w0, time_budget=35.0, epochs=3)
    si, qi, trace = profile_compression(w_warm, data, theta=theta)
    out = {"static": (DEFAULT_SET_S[si], DEFAULT_SET_Q[qi]),
           "schedule": make_schedule(si, qi, total_rounds=total_rounds),
           "trace_len": len(trace)}
    _POINTS_CACHE[key] = out
    return out


def teasq_schedule(scale: Scale, iid: bool = True, theta: float = 0.02,
                   total_rounds: int = 60):
    return compression_points(scale, iid, theta, total_rounds)["schedule"]


def record(table: str, rows: List[Dict]) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)), exist_ok=True)
    db = {}
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            db = json.load(f)
    db[table] = rows
    with open(RESULTS_PATH, "w") as f:
        json.dump(db, f, indent=1)


def print_csv(table: str, rows: List[Dict], derived_key: str = "final_acc"):
    for r in rows:
        name = f"{table}/{r['method']}" + ("_iid" if r["iid"] else "_noniid")
        extra = "_".join(f"{k}{v}" for k, v in r.get("kw", {}).items()
                         if k in ("c_fraction", "mu", "alpha", "p_s", "p_q"))
        if extra:
            name += "_" + extra
        acc = r["history"][-1][2]
        print(f"{name},{r['us_per_round']:.1f},{acc:.4f}")


def std_argparser(desc: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=desc)
    ap.add_argument("--full", action="store_true",
                    help="paper scale (100 devices, 60k samples, 300s)")
    ap.add_argument("--backend", choices=("engine", "legacy"),
                    default="engine",
                    help="protocol runner: strategy engine or legacy sim")
    ap.add_argument("--cohort", type=int, default=0,
                    help="engine cohort size (>0 = vectorized training)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the benchmark region and dump the top-20"
                         " cumulative rows next to the results JSON")
    return ap


def scale_from_args(args) -> Scale:
    return Scale(args.full, backend=getattr(args, "backend", "engine"),
                 cohort_size=getattr(args, "cohort", 0))
