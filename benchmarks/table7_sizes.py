"""Table 7: maximum transmitted model size per method (wire bytes)."""
from benchmarks.common import (Scale, compression_points, record,
                               simulate, std_argparser)


def run(scale: Scale):
    rows = []
    for iid in (True, False):
        pts = compression_points(scale, iid=iid)
        sch = pts["schedule"]
        p_s, p_q = pts["static"]
        short = dict(time_budget=scale.budget_for(iid) / 3)
        for method, kw in [("fedavg", {}), ("tea", {}),
                           ("teastatic", dict(p_s=p_s, p_q=p_q)),
                           ("teasq", dict(p_s=p_s, p_q=p_q, schedule=sch))]:
            r = simulate(scale, method, iid=iid, **short, **kw)
            h = r["history"][-1]
            r["max_up_kb"] = h[5] / 1024
            r["max_down_kb"] = h[6] / 1024
            rows.append(r)
    record("table7_sizes", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    rows = run(Scale(args.full))
    for r in rows:
        tag = "iid" if r["iid"] else "noniid"
        print(f"table7/{r['method']}_{tag},{r['us_per_round']:.1f},"
              f"up={r['max_up_kb']:.1f}KB down={r['max_down_kb']:.1f}KB")


if __name__ == "__main__":
    main()
