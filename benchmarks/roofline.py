"""Roofline analysis from the dry-run's compiled artifacts.

For each (arch x shape x mesh) record in results/dryrun_*.json:

  compute term    = HLO_FLOPs/device   / 197e12   (TPU v5e bf16 peak)
  memory term     = HLO_bytes/device   / 819e9    (HBM bandwidth)
  collective term = coll_bytes/device  / 50e9     (ICI link bandwidth)

HLO_FLOPs and HLO_bytes come from compiled.cost_analysis() (per-partition
module); collective bytes from the trip-count-aware HLO parser in
launch/dryrun.py.  MODEL_FLOPS is the analytic 6*N*D (train) / 2*N*D
(prefill/decode), N = active params, D = tokens — the ratio against
HLO_FLOPs*chips exposes remat/dispatch waste (>1x expected with per-layer
remat: ~1.33x recompute, MoE capacity overcompute, attention not in 6ND).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPES_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    toks = SHAPES_TOKENS[rec["shape"]]
    n = rec["active_params"]
    if rec["shape"] == "train_4k":
        mult = 6 * rec.get("fed", {}).get("local_steps", 1)
    else:
        mult = 2
    return float(mult * n * toks)


def analyze(rec: Dict, chips: int) -> Optional[Dict]:
    if "cost" not in rec or "collectives" not in rec:
        return None
    # prefer the trip-count-aware estimates (XLA cost_analysis counts while
    # bodies once; scanned stacks undercount by ~n_layers)
    flops_dev = rec["cost"].get("flops_trip_aware") or \
        rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes_trip_aware") or \
        rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"].get("total", 0.0)
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_n = coll_dev / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    ratio = mf / max(flops_dev * chips, 1.0)
    bound = max(terms.values())
    mfu_bound = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": rec.get("step"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_per_dev": flops_dev,
        "useful_ratio": ratio,
        "mfu_upper_bound": mfu_bound,
        "temp_bytes": rec.get("memory", {}).get("temp_size_in_bytes"),
    }


def advice(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink exchanged bytes (lower p_q, sparsify on the wire) or "
                "switch schedule gather->reduce-scatter")
    if d == "memory":
        return ("cut activation/logit footprint (bf16 logits, chunked vocab "
                "loss, tighter remat policy)")
    return ("raise arithmetic intensity (larger per-device batch, fuse "
            "elementwise chains, avoid recompute)")


def load(paths: List[str]) -> List[Dict]:
    out = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                out.extend(json.load(f))
    return out


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | MFU bound |\n|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['mfu_upper_bound']*100:.1f}% |")
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inputs", nargs="*", default=[
        "results/dryrun_single.json"])
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = []
    for rec in load(args.inputs):
        if "error" in rec:
            continue
        chips = 512 if rec["mesh"] == "2x16x16" else 256
        row = analyze(rec, chips)
        if row:
            row["advice"] = advice(row)
            rows.append(row)
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if args.markdown:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
                  f"dom={r['dominant']}")


if __name__ == "__main__":
    main()
