"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs the quick-scale suite and
prints ``name,us_per_call,derived`` CSV.  ``--full`` uses the paper's scale;
``--only fig2,table7`` selects subsets.  Roofline rows are appended from the
dry-run JSONs if present (run repro.launch.dryrun first for those).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (engine_scale, fig2_mu, fig3_c_fraction, fig6_alpha,
                        fig8_ablation, fig9_sota, table3_6_compression,
                        table7_sizes)
from benchmarks.common import Scale, print_csv

SUITES = {
    "fig2": (fig2_mu, "fig2_mu"),
    "fig3_5": (fig3_c_fraction, "fig3_5_c"),
    "fig6": (fig6_alpha, "fig6_alpha"),
    "table3_6": (table3_6_compression, "table3_6"),
    "fig8": (fig8_ablation, "fig8_ablation"),
    "table7": (table7_sizes, "table7"),
    "fig9": (fig9_sota, "fig9_sota"),
    "engine_scale": (engine_scale, "engine_scale"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()

    # engine_scale is a wall-clock race at N=1000 — opt-in via --only
    names = [n.strip() for n in args.only.split(",") if n.strip()] or \
        [n for n in SUITES if n != "engine_scale"]
    scale = Scale(args.full)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        mod, tag = SUITES[name]
        try:
            rows = mod.run(scale)
            if name == "table7":
                for r in rows:
                    d = "iid" if r["iid"] else "noniid"
                    print(f"table7/{r['method']}_{d},{r['us_per_round']:.1f},"
                          f"max_up_{r['max_up_kb']:.1f}KB")
            elif name == "engine_scale":
                for r in rows:
                    print(f"engine_scale/{r['backend']}_n{r['n_devices']},"
                          f"{r['wall_s'] * 1e6 / max(r['rounds'], 1):.1f},"
                          f"wall={r['wall_s']:.1f}s_rounds={r['rounds']}")
            else:
                print_csv(tag, rows)
        except Exception as e:  # pragma: no cover
            print(f"{tag}/ERROR,0,{e!r}", file=sys.stderr)
            raise
        print(f"# {name} done at {time.time()-t0:.0f}s", file=sys.stderr)

    # roofline rows (from dry-run artifacts, if present)
    try:
        from benchmarks import roofline
        rows = roofline.load(["results/dryrun_single.json"])
        for rec in rows:
            if "error" in rec:
                continue
            r = roofline.analyze(rec, 256)
            if r:
                dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
                print(f"roofline/{r['arch']}_{r['shape']},{dom_s*1e6:.1f},"
                      f"dom={r['dominant']}")
    except Exception as e:  # pragma: no cover
        print(f"# roofline skipped: {e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
