"""Codec throughput: encode/decode MB/s + bytes-on-wire per registered codec.

Runs every codec in ``repro.core.codecs.CODECS`` on the FMNIST CNN pytree
(the paper's model) across the compression grid p_s x p_q, measuring wall
encode/decode throughput against the dense f32 payload size and the metered
wire bytes (for ``PackedBitstreamCodec`` this is ``len()`` of the actual
byte string; the packed codec must price identically to the analytic
``expected_pytree_wire_bytes``).

On top of the registry codecs, two explicit packed-codec variants pin the
fused-emitter speedup (the ISSUE-8 tentpole):

* ``packed_fused``  — ``PackedBitstreamCodec(fused=True)``, deterministic
  rounding: the one-pass fused emitter (``repro.kernels.fused_pack``);
* ``packed_host``   — ``fused=False``, deterministic rounding: the
  multi-pass ``compress_tensor`` -> ``pack_segments`` oracle pipeline.

(The plain ``packed`` row keeps stochastic-QSGD encode with the shared RNG
— the engines' configuration — so its numbers stay comparable across
revisions.)  Each ``packed_fused`` measurement also asserts the fused byte
stream is bit-identical to the oracle's and that ``len(bytes)`` equals the
analytic price, so the benchmark cannot report a fast-but-wrong emitter.

Results MERGE into results/codec_throughput.json keyed by
``(codec, p_s, p_q)`` — same idea as ``_merge_results`` in
``benchmarks.engine_scale`` — so a partial re-run (one codec, one grid
point) does not clobber the rest of the table.

  PYTHONPATH=src python -m benchmarks.codec_throughput [--reps 3]
      [--host-tuning] [--host-devices N]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import host_tuning_active, maybe_reexec_host_tuned
from repro.core.codecs import (CODECS, Codec, PackedBitstreamCodec,
                               resolve_codec)
from repro.core.compression import (expected_pytree_wire_bytes,
                                    pytree_dense_bytes)
from repro.models.cnn import init_cnn

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "codec_throughput.json")
GRID_PS = (0.1, 0.25, 0.5)
GRID_PQ = (2, 4, 8)

# non-registry benchmark variants: name -> (codec factory, stochastic rng?)
VARIANTS: Dict[str, Callable[[float, int], Codec]] = {
    "packed_fused": lambda p_s, p_q: PackedBitstreamCodec(p_s, p_q, fused=True),
    "packed_host": lambda p_s, p_q: PackedBitstreamCodec(p_s, p_q, fused=False),
}


def _sync(tree: Any) -> Any:
    """Force any pending device computation (threshold codec is lazy jnp)."""
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return tree


def bench_codec(name: str, tree: Any, p_s: float, p_q: int,
                reps: int = 3) -> Dict[str, Any]:
    if name in VARIANTS:
        codec = VARIANTS[name](p_s, p_q)
        rng = None             # deterministic: exercises the fused seam
    else:
        codec = resolve_codec(name, p_s, p_q)
        rng = np.random.RandomState(0)
    dense_mb = pytree_dense_bytes(tree) / 1e6

    wire = codec.encode(tree, rng=rng)     # warmup (jit compiles)
    _sync(codec.decode(wire))
    # identity/threshold decode just returns the (already materialized)
    # payload — timing that no-op would report timer-resolution "MB/s"
    passthrough = codec.decode(wire) is wire.payload

    row: Dict[str, Any] = {
        "codec": name, "resolved": codec.name, "p_s": p_s, "p_q": p_q}
    if name == "packed_fused":
        # a fast emitter only counts if it is the SAME stream: bit-identical
        # to the multi-pass oracle, length == the analytic price
        oracle = VARIANTS["packed_host"](p_s, p_q).encode(tree)
        assert wire.payload == oracle.payload, (p_s, p_q)
        assert len(wire.payload) == expected_pytree_wire_bytes(tree, p_s, p_q)
        row["bit_identical_to_host"] = True

    enc_s, dec_s = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        wire = codec.encode(tree, rng=rng)
        _sync(wire.payload)
        enc_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _sync(codec.decode(wire))
        dec_s.append(time.perf_counter() - t0)

    row.update({
        "wire_bytes": wire.nbytes,
        "expected_bytes": expected_pytree_wire_bytes(tree, codec.p_s,
                                                     codec.p_q),
        "dense_bytes": pytree_dense_bytes(tree),
        "compression_x": round(pytree_dense_bytes(tree) / wire.nbytes, 2),
        "encode_mbps": round(dense_mb / min(enc_s), 2),
        "decode_mbps": (None if passthrough
                        else round(dense_mb / min(dec_s), 2)),
        "host_tuned": host_tuning_active(),
    })
    return row


def _merge_rows(path: str, rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Merge new rows into the existing results file keyed by
    ``(codec, p_s, p_q)`` — the list-of-rows analogue of
    ``benchmarks.engine_scale._merge_results`` — so partial re-runs update
    their grid points in place instead of clobbering the whole table."""
    merged: Dict[tuple, Dict[str, Any]] = {}
    if os.path.exists(path):
        with open(path) as f:
            for r in json.load(f):
                merged[(r["codec"], r["p_s"], r["p_q"])] = r
    for r in rows:
        merged[(r["codec"], r["p_s"], r["p_q"])] = r
    return [merged[k] for k in sorted(merged)]


def run(reps: int = 3, grid_ps: Sequence[float] = GRID_PS,
        grid_pq: Sequence[int] = GRID_PQ,
        codecs: Optional[Sequence[str]] = None,
        out_path: Optional[str] = RESULTS_PATH) -> List[Dict[str, Any]]:
    tree = init_cnn(jax.random.PRNGKey(0))
    rows = []
    names = (codecs if codecs is not None
             else sorted(CODECS) + sorted(VARIANTS))
    for name in names:
        for p_s in grid_ps:
            for p_q in grid_pq:
                row = bench_codec(name, tree, p_s, p_q, reps=reps)
                rows.append(row)
                dec = (f"{row['decode_mbps']:8.1f}MB/s"
                       if row['decode_mbps'] is not None else "     n/a")
                print(f"[{row['codec']:12s}] p_s={p_s:4.2f} p_q={p_q:2d} "
                      f"wire={row['wire_bytes']:8d}B "
                      f"({row['compression_x']:5.1f}x) "
                      f"enc={row['encode_mbps']:8.1f}MB/s "
                      f"dec={dec}", flush=True)
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
        merged = _merge_rows(out_path, rows)
        with open(out_path, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"[codec_throughput] {len(rows)} rows "
              f"({len(merged)} total) -> {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--host-tuning", action="store_true",
                    help="re-exec with tcmalloc LD_PRELOAD (same setup as "
                         "the engine bench; see benchmarks.common)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="with --host-tuning: partition the host CPU into N "
                         "logical XLA devices")
    args = ap.parse_args()
    maybe_reexec_host_tuned(args.host_tuning, args.host_devices)
    run(reps=args.reps, out_path=args.out)


if __name__ == "__main__":
    main()
