"""Codec throughput: encode/decode MB/s + bytes-on-wire per registered codec.

Runs every codec in ``repro.core.codecs.CODECS`` on the FMNIST CNN pytree
(the paper's model) across the compression grid p_s x p_q, measuring wall
encode/decode throughput against the dense f32 payload size and the metered
wire bytes (for ``PackedBitstreamCodec`` this is ``len()`` of the actual
byte string; the packed codec must price identically to the analytic
``expected_pytree_wire_bytes``).  Results land in
results/codec_throughput.json.

  PYTHONPATH=src python -m benchmarks.codec_throughput [--reps 3]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.codecs import CODECS, resolve_codec
from repro.core.compression import (expected_pytree_wire_bytes,
                                    pytree_dense_bytes)
from repro.models.cnn import init_cnn

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "codec_throughput.json")
GRID_PS = (0.1, 0.25, 0.5)
GRID_PQ = (2, 4, 8)


def _sync(tree: Any) -> Any:
    """Force any pending device computation (threshold codec is lazy jnp)."""
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return tree


def bench_codec(name: str, tree: Any, p_s: float, p_q: int,
                reps: int = 3) -> Dict[str, Any]:
    codec = resolve_codec(name, p_s, p_q)
    dense_mb = pytree_dense_bytes(tree) / 1e6
    rng = np.random.RandomState(0)

    wire = codec.encode(tree, rng=rng)     # warmup (jit compiles)
    _sync(codec.decode(wire))
    # identity/threshold decode just returns the (already materialized)
    # payload — timing that no-op would report timer-resolution "MB/s"
    passthrough = codec.decode(wire) is wire.payload

    enc_s, dec_s = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        wire = codec.encode(tree, rng=rng)
        _sync(wire.payload)
        enc_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _sync(codec.decode(wire))
        dec_s.append(time.perf_counter() - t0)

    return {
        "codec": name, "resolved": codec.name, "p_s": p_s, "p_q": p_q,
        "wire_bytes": wire.nbytes,
        "expected_bytes": expected_pytree_wire_bytes(tree, codec.p_s,
                                                     codec.p_q),
        "dense_bytes": pytree_dense_bytes(tree),
        "compression_x": round(pytree_dense_bytes(tree) / wire.nbytes, 2),
        "encode_mbps": round(dense_mb / min(enc_s), 2),
        "decode_mbps": (None if passthrough
                        else round(dense_mb / min(dec_s), 2)),
    }


def run(reps: int = 3, grid_ps: Sequence[float] = GRID_PS,
        grid_pq: Sequence[int] = GRID_PQ,
        codecs: Optional[Sequence[str]] = None,
        out_path: Optional[str] = RESULTS_PATH) -> List[Dict[str, Any]]:
    tree = init_cnn(jax.random.PRNGKey(0))
    rows = []
    for name in (codecs if codecs is not None else sorted(CODECS)):
        for p_s in grid_ps:
            for p_q in grid_pq:
                row = bench_codec(name, tree, p_s, p_q, reps=reps)
                rows.append(row)
                dec = (f"{row['decode_mbps']:8.1f}MB/s"
                       if row['decode_mbps'] is not None else "     n/a")
                print(f"[{row['codec']:9s}] p_s={p_s:4.2f} p_q={p_q:2d} "
                      f"wire={row['wire_bytes']:8d}B "
                      f"({row['compression_x']:5.1f}x) "
                      f"enc={row['encode_mbps']:8.1f}MB/s "
                      f"dec={dec}", flush=True)
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"[codec_throughput] {len(rows)} rows -> {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()
    run(reps=args.reps, out_path=args.out)


if __name__ == "__main__":
    main()
