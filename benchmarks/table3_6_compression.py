"""Tables 3-6 + Fig. 7: FedAvg vs TEA-Fed vs TEAStatic-Fed vs TEASQ-Fed —
highest accuracy within time budgets and time to target accuracy, IID and
non-IID."""
from benchmarks.common import (Scale, best_acc_within, compression_points,
                               print_csv, record, simulate, std_argparser,
                               time_to_acc)

BUDGET_FRACS = [1 / 6, 1 / 3, 1 / 2, 2 / 3, 5 / 6, 1.0]


def run(scale: Scale):
    rows = []
    for iid in (True, False):
        pts = compression_points(scale, iid=iid)
        sch = pts["schedule"]
        static = dict(p_s=pts["static"][0], p_q=pts["static"][1])
        rows.append(simulate(scale, "fedavg", iid=iid))
        rows.append(simulate(scale, "tea", iid=iid))
        r = simulate(scale, "teastatic", iid=iid, **static)
        r["kw"].update(static)
        rows.append(r)
        r = simulate(scale, "teasq", iid=iid, schedule=sch, **static)
        r["kw"]["schedule"] = f"decay(s0={sch.p_s0_idx},q0={sch.p_q0_idx})"
        rows.append(r)
    # derive table cells
    for r in rows:
        hist = [type("H", (), dict(time=h[0], accuracy=h[2]))()
                for h in r["history"]]
        b = scale.budget_for(r["iid"])
        r["acc_at_budget"] = {f"{f:.2f}": best_acc_within(hist, f * b)
                              for f in BUDGET_FRACS}
        final = max(h[2] for h in r["history"])
        r["time_to_80pct_final"] = time_to_acc(hist, 0.8 * final)
    record("table3_6_compression", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    rows = run(Scale(args.full))
    print_csv("table3_6", rows)
    for r in rows:
        tag = ("iid" if r["iid"] else "noniid")
        cells = " ".join(f"{k}:{v:.3f}" for k, v in r["acc_at_budget"].items())
        print(f"# {r['method']}_{tag} acc@budget {cells} "
              f"t80={r['time_to_80pct_final']}")


if __name__ == "__main__":
    main()
