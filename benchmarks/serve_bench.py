"""Serving throughput: continuous batching vs. serial one-at-a-time decode.

Drives the same request workload (``requests`` random prompts, ``gen``
greedy tokens each) through the FL->serve front door twice on the tiny
FL transformer LM (``repro.fl.tasks`` ``transformer_lm``):

* ``serial``     — batch=1 ``repro.launch.serve.generate`` per request,
  back to back: the no-batching baseline a naive server would run.  Note
  ``generate``'s loop samples host-side every step, so the gap measures
  the whole serving stack (batching + the batcher's sync-free device
  loop), not batching alone;
* ``continuous`` — one ``ContinuousBatcher`` with ``batch`` decode
  slots, admitting queued requests into free slots every step.

Both paths produce identical greedy tokens (tests/test_serve.py pins
that), so the comparison is pure scheduling: tokens/s plus p50/p99
per-request completion latency (submit-at-t0 to last token).  The
continuous row records ``speedup_x`` over the serial baseline; the
ROADMAP target is >= 1.5x at batch >= 4.

Results MERGE into results/serve_bench.json keyed by
``(mode, batch, requests, prompt_len, gen)`` so re-runs at one batch
size update their row in place.

  PYTHONPATH=src python -m benchmarks.serve_bench [--batch 4]
      [--requests 8] [--gen 16]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.tasks import get_task
from repro.launch.serve import ContinuousBatcher, generate

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "serve_bench.json")


def _percentiles(lat_s: List[float]) -> Dict[str, float]:
    ms = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(ms, 50)), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2)}


def _bench_serial(params, cfg, prompts: List[np.ndarray], gen: int
                  ) -> Dict[str, Any]:
    """One request at a time, batch=1 ``generate`` — every request's
    latency includes all the requests queued ahead of it."""
    # warmup: compile prefill + decode step outside the timed region
    generate(params, cfg, jnp.asarray(prompts[0][None]), gen)
    t0 = time.perf_counter()
    lat = []
    for p in prompts:
        generate(params, cfg, jnp.asarray(p[None]), gen)
        lat.append(time.perf_counter() - t0)
    dt = time.perf_counter() - t0
    return {"seconds": dt, "tokens_per_s": len(prompts) * gen / dt,
            **_percentiles(lat)}


def _bench_continuous(params, cfg, prompts: List[np.ndarray], gen: int,
                      batch: int, cache_len: int) -> Dict[str, Any]:
    # warmup batcher of the same geometry: compile prefill, slot insert
    # and the batched decode step outside the timed region
    warm = ContinuousBatcher(params, cfg, slots=batch, cache_len=cache_len)
    warm.run(prompts[:batch], min(gen, 2))
    cb = ContinuousBatcher(params, cfg, slots=batch, cache_len=cache_len)
    t0 = time.perf_counter()
    outs, lat = cb.run(prompts, gen)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    return {"seconds": dt, "tokens_per_s": toks / dt,
            "decode_steps": cb.steps, **_percentiles(lat)}


def _merge_rows(path: str, rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Keyed row merge (same idea as ``benchmarks.codec_throughput``):
    partial re-runs update their rows in place."""
    key = lambda r: (r["mode"], r["batch"], r["requests"],
                     r["prompt_len"], r["gen"])
    merged: Dict[tuple, Dict[str, Any]] = {}
    if os.path.exists(path):
        with open(path) as f:
            for r in json.load(f):
                merged[key(r)] = r
    for r in rows:
        merged[key(r)] = r
    return [merged[k] for k in sorted(merged)]


def run(batch: int = 4, requests: int = 16, prompt_len: int = 8,
        gen: int = 32, task: str = "transformer_lm", seed: int = 0,
        out_path: Optional[str] = RESULTS_PATH) -> List[Dict[str, Any]]:
    t = get_task(task)
    cfg = t.model_cfg
    assert cfg is not None, f"task {task!r} has no ModelConfig to serve"
    params = t.init_params(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab, prompt_len).astype(np.int32)
               for _ in range(requests)]
    base = {"task": task, "model": cfg.name, "batch": batch,
            "requests": requests, "prompt_len": prompt_len, "gen": gen}

    serial = _bench_serial(params, cfg, prompts, gen)
    cont = _bench_continuous(params, cfg, prompts, gen, batch,
                             prompt_len + gen)
    speedup = cont["tokens_per_s"] / serial["tokens_per_s"]
    rows = [
        {**base, "mode": "serial", "batch": 1,
         **{k: round(v, 2) if isinstance(v, float) else v
            for k, v in serial.items()}},
        {**base, "mode": "continuous",
         **{k: round(v, 2) if isinstance(v, float) else v
            for k, v in cont.items()},
         "speedup_x": round(speedup, 2)},
    ]
    for r in rows:
        print(f"[{r['mode']:10s}] batch={r['batch']} requests={requests} "
              f"gen={gen} {r['tokens_per_s']:8.1f} tok/s "
              f"p50={r['p50_ms']:7.1f}ms p99={r['p99_ms']:7.1f}ms",
              flush=True)
    print(f"[serve_bench] continuous speedup over serial: {speedup:.2f}x")
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
        merged = _merge_rows(out_path, rows)
        with open(out_path, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"[serve_bench] {len(rows)} rows ({len(merged)} total) "
              f"-> {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--task", default="transformer_lm")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()
    run(batch=args.batch, requests=args.requests, prompt_len=args.prompt_len,
        gen=args.gen, task=args.task, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
