"""Fig. 8: compression ablation — TEA vs TEAS (sparsification only) vs TEAQ
(quantization only) vs TEASQ (both)."""
from benchmarks.common import (Scale, compression_points, print_csv,
                               record, scale_from_args, simulate,
                               std_argparser)


def run(scale: Scale):
    p_s, p_q = compression_points(scale, iid=False)["static"]
    rows = [
        simulate(scale, "tea", iid=False),
        simulate(scale, "teas", iid=False, p_s=p_s),
        simulate(scale, "teaq", iid=False, p_q=p_q),
        simulate(scale, "teastatic", iid=False, p_s=p_s, p_q=p_q),
    ]
    record("fig8_ablation", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    print_csv("fig8_ablation", run(scale_from_args(args)))


if __name__ == "__main__":
    main()
