"""Fig. 6: robustness to the mixing hyper-parameter alpha."""
from benchmarks.common import (Scale, print_csv, record,
                               scale_from_args, simulate, std_argparser)

ALPHAS = [0.2, 0.6, 0.9]


def run(scale: Scale):
    rows = []
    for iid in (True, False):
        for a in ALPHAS:
            r = simulate(scale, "tea", iid=iid, alpha=a)
            r["kw"]["alpha"] = a
            rows.append(r)
    record("fig6_alpha", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    print_csv("fig6_alpha", run(scale_from_args(args)))


if __name__ == "__main__":
    main()
