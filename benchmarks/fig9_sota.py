"""Fig. 9: comparison with SOTA baselines — PORT, ASO-Fed (async) and MOON
(synchronous, model-contrastive).  See DESIGN.md for the faithful-but-
simplified baseline implementations."""
from benchmarks.common import (Scale, compression_points, print_csv,
                               record, scale_from_args, simulate,
                               std_argparser)


def run(scale: Scale):
    pts = compression_points(scale, iid=False)
    sch = pts["schedule"]
    p_s, p_q = pts["static"]
    rows = [
        simulate(scale, "teasq", iid=False, p_s=p_s, p_q=p_q, schedule=sch),
        simulate(scale, "port", iid=False, c_fraction=0.3),
        simulate(scale, "asofed", iid=False),
        simulate(scale, "moon", iid=False),
    ]
    record("fig9_sota", rows)
    return rows


def main():
    args = std_argparser(__doc__).parse_args()
    print_csv("fig9_sota", run(scale_from_args(args)))


if __name__ == "__main__":
    main()
