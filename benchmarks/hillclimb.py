"""§Perf hillclimb driver: lower named variants of the three chosen
(arch x shape) pairs and record their roofline terms.

Pairs (chosen from the baseline roofline table):
  A. jamba-v0.1-52b  x train_4k   — worst roofline fraction AND most
     collective-bound (fed exchange of 52B MoE params).
  B. granite-34b     x decode_32k — memory-bound serving (MQA kv=1: KV cache
     unshardable over heads).
  C. qwen3-1.7b      x train_4k   — most representative of the paper's
     technique (compressed model exchange on an FL-plausible model size).

Usage:  PYTHONPATH=src python -m benchmarks.hillclimb --pair C
Results append to results/perf/hillclimb.json.
"""
import argparse
import json
import os
import sys

VARIANTS = {
    # pair C (and A): fed-exchange schedule ladder, + memory lever
    "C": [
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_f32"),
         "tea_fed_f32_gather (paper TEA-Fed baseline, no compression)"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_q", p_q=8),
         "teasq_int8_gather (paper-faithful TEASQ wire)"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_q", p_q=4),
         "beyond: int4 wire (s4 gather, 8x vs f32)"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="psum"),
         "beyond: weighted reduce (ring all-reduce) instead of gather"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_q", p_q=8,
                                        loss_chunk=256),
         "beyond: + chunked-vocab loss (memory term)"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_q", p_q=8,
                                        group_parallelism="dp"),
         "beyond: group-internal DP instead of TP (model fits per chip)"),
        ("qwen3_1_7b", "train_4k", dict(fed_schedule="gather_q", p_q=8,
                                        group_parallelism="dp",
                                        loss_chunk=256),
         "beyond: group-DP + chunked loss (final config)"),
    ],
    "A": [
        ("jamba_v0_1_52b", "train_4k", dict(fed_schedule="gather_f32"),
         "tea_fed_f32_gather"),
        ("jamba_v0_1_52b", "train_4k", dict(fed_schedule="gather_q", p_q=8),
         "teasq_int8_gather"),
        ("jamba_v0_1_52b", "train_4k", dict(fed_schedule="psum"),
         "beyond: weighted reduce"),
        ("jamba_v0_1_52b", "train_4k", dict(fed_schedule="psum",
                                            loss_chunk=256),
         "beyond: psum + chunked loss"),
    ],
    "B": [
        ("granite_34b", "decode_32k", dict(), "baseline bf16 full KV"),
        ("granite_34b", "decode_32k", dict(kv_quant=True),
         "paper-themed: int8-quantized KV cache"),
        ("granite_34b", "decode_32k", dict(seq_shard_kv=True),
         "beyond: sequence-sharded KV + flash-merge psum"),
        ("granite_34b", "decode_32k", dict(seq_shard_kv=True, kv_quant=False),
         "(dup guard)"),
    ],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=["A", "B", "C"])
    ap.add_argument("--out", default="results/perf/hillclimb.json")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one  # sets XLA_FLAGS on import

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    seen = {(r["arch"], r["shape"], r.get("variant")) for r in results}

    for arch, shape, kw, label in VARIANTS[args.pair]:
        if label == "(dup guard)":
            continue
        key = (arch, shape, label)
        if key in seen:
            print(f"[hillclimb] skip {label} (done)")
            continue
        rec = run_one(arch, shape, variant=label, **kw)
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)
        c = rec.get("collectives", {})
        cost = rec.get("cost", {})
        print(f"[hillclimb {args.pair}] {label}\n"
              f"    flops(trip)={cost.get('flops_trip_aware', 0):.3e} "
              f"bytes(trip)={cost.get('bytes_trip_aware', 0):.3e} "
              f"coll={c.get('total', 0):.3e}B "
              f"temp={rec.get('memory', {}).get('temp_size_in_bytes', 0)/1e9:.1f}GB")


if __name__ == "__main__":
    main()
