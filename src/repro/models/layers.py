"""Primitive layers: norms, rotary, SwiGLU MLP, embeddings (pure JAX)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard


def _uniform(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return _uniform(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


# -- norms --------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(x, scale, eps: float = 1e-5):
    """qk-norm: RMS over head_dim (last axis) with learned scale (head_dim,)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# -- rotary -------------------------------------------------------------
def rotary(x, positions, theta: float = 10000.0):
    """Apply rotary embedding. x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLP: SwiGLU (gated, default) or GELU (non-gated, e.g. granite) -------
def mlp_init(key, d: int, f: int, dtype=jnp.float32, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k1, d, f, dtype)
    return p


def mlp(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = shard(h, "batch", "seq", "ffn")
    return h @ params["w_down"]


# -- embeddings ----------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return _uniform(key, (vocab, d), 1.0 / math.sqrt(d), dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def lm_head(x, table: Optional[jax.Array], head: Optional[jax.Array]):
    """Project to vocab logits (tied table or separate head). f32 logits."""
    if head is not None:
        logits = x @ head
    else:
        logits = x @ table.T
    return shard(logits.astype(jnp.float32), "batch", "seq", "vocab")
