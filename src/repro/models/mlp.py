"""One-hidden-layer MLP on 28x28 grayscale images.

The smallest non-CNN family in the FL task registry
(``repro.fl.tasks.TASKS`` entry ``fmnist_mlp``): cheap enough for the
conformance suite's end-to-end runs on a ~4 ms/dispatch CPU, while still
exercising every protocol/codec path with a non-CNN parameter pytree.

Mirrors the CNN module's layout: serial ``mlp_forward``/``mlp_loss``/
``mlp_accuracy``/``mlp_features`` plus the vectorized per-device-weights
``mlp_cohort_loss`` (batched einsum GEMMs — same form as the CNN cohort
head, and trivially safe from the vmap-of-conv grouped-convolution trap).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

MLP_HIDDEN = 64


def init_mlp(key, n_classes: int = 10, hidden: int = MLP_HIDDEN
             ) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    d_in = 28 * 28

    def unif(k, shape, fan_in):
        s = 1.0 / np.sqrt(fan_in)
        return jax.random.uniform(k, shape, jnp.float32, -s, s)

    return {"w1": unif(k1, (d_in, hidden), d_in),
            "b1": jnp.zeros((hidden,)),
            "w2": unif(k2, (hidden, n_classes), hidden),
            "b2": jnp.zeros((n_classes,))}


def mlp_features(params, images: jax.Array) -> jax.Array:
    """Penultimate representation (MOON's contrastive term)."""
    x = images.reshape(images.shape[0], -1)
    return jax.nn.relu(x @ params["w1"] + params["b1"])


def mlp_forward(params, images: jax.Array) -> jax.Array:
    """images: (B, 28, 28, 1) -> logits (B, n_classes)."""
    return mlp_features(params, images) @ params["w2"] + params["b2"]


def mlp_loss(params, batch) -> jax.Array:
    logp = jax.nn.log_softmax(mlp_forward(params, batch["images"]), axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1).mean()


def mlp_accuracy(params, images, labels) -> jax.Array:
    return (mlp_forward(params, images).argmax(-1) == labels).mean()


def mlp_cohort_loss(params, images: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-device-weights MLP: leaves (C, ...), images (C, B, 28, 28, 1)."""
    x = images.reshape(images.shape[0], images.shape[1], -1)
    h = jax.nn.relu(jnp.einsum("cbk,cko->cbo", x, params["w1"])
                    + params["b1"][:, None, :])
    logits = (jnp.einsum("cbk,cko->cbo", h, params["w2"])
              + params["b2"][:, None, :])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
