"""Architecture assembly: dense / MoE / SSM / hybrid decoders, enc-dec, VLM.

All stacks use ``lax.scan`` over stacked layer parameters so the HLO stays
small at 88 layers.  The hybrid (Jamba) stack scans over *groups* of
``attn_every`` layers (7 mamba + 1 attention per group, FFN alternating
dense/MoE) since the layer pattern repeats at that period.

Public entry points:
  init_model(key, cfg, dtype)             -> params
  forward(params, batch, cfg)             -> (logits, aux)   # train / prefill
  init_decode_state(cfg, batch, cache_len, dtype, rolling)   -> cache pytree
  decode_step(params, tokens, pos, cfg, cache)  -> (logits, new cache)
  lm_loss(params, batch, cfg)             -> (loss, aux)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_init, embed_lookup, lm_head,
                                 mlp, mlp_init, rmsnorm, rmsnorm_init)
from repro.sharding.rules import shard


# ======================================================================
# init
# ======================================================================
def _init_uniform_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype),
         "norm2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.is_ssm_only:
        p["ssm"] = ssm_mod.ssm_init(k1, cfg, dtype)
        del p["norm2"]
        return p
    p["attn"] = attn.attn_init(k1, cfg, dtype)
    if cfg.is_moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    elif cfg.d_ff > 0:
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp)
    else:
        del p["norm2"]
    return p


def _init_hybrid_group(key, cfg, dtype):
    """One Jamba group: (attn_every-1) mamba + 1 attn; FFN dense/MoE alternating."""
    ae = cfg.attn_every
    n_moe = ae // cfg.moe_every
    n_dense = ae - n_moe
    keys = jax.random.split(key, 4)
    ssm_keys = jax.random.split(keys[0], ae - 1)
    dense_keys = jax.random.split(keys[2], max(n_dense, 1))
    moe_keys = jax.random.split(keys[3], max(n_moe, 1))
    g = {
        "ssm": jax.vmap(lambda k: ssm_mod.ssm_init(k, cfg, dtype))(ssm_keys),
        "attn": attn.attn_init(keys[1], cfg, dtype),
        "norm1": jax.vmap(lambda _: rmsnorm_init(cfg.d_model, dtype))(
            jnp.arange(ae)),
        "norm2": jax.vmap(lambda _: rmsnorm_init(cfg.d_model, dtype))(
            jnp.arange(ae)),
    }
    if n_dense:
        g["ffn"] = jax.vmap(
            lambda k: mlp_init(k, cfg.d_model, cfg.d_ff, dtype,
                               gated=cfg.gated_mlp))(dense_keys)
    if n_moe:
        g["moe"] = jax.vmap(
            lambda k: moe_mod.moe_init(k, cfg, dtype))(moe_keys)
    return g


def _init_encdec_layer(key, cfg, dtype, decoder: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype),
         "attn": attn.attn_init(k1, cfg, dtype),
         "norm_ffn": rmsnorm_init(cfg.d_model, dtype),
         "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype,
                         gated=cfg.gated_mlp)}
    if decoder:
        p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["xattn"] = attn.attn_init(k2, cfg, dtype, cross=True)
    return p


def init_model(key, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    ke, kl, kh, kp = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.vocab, dtype)

    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(kl, cfg.n_enc_layers + 1)
        dec_keys = jax.random.split(enc_keys[-1], cfg.n_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_encdec_layer(k, cfg, dtype, False))(enc_keys[:-1])
        params["layers"] = jax.vmap(
            lambda k: _init_encdec_layer(k, cfg, dtype, True))(dec_keys)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    elif cfg.is_hybrid:
        n_groups = cfg.n_layers // cfg.attn_every
        gkeys = jax.random.split(kl, n_groups)
        params["layers"] = jax.vmap(
            lambda k: _init_hybrid_group(k, cfg, dtype))(gkeys)
    else:
        lkeys = jax.random.split(kl, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_uniform_layer(k, cfg, dtype))(lkeys)

    if cfg.n_patches:  # VLM: projector from (stubbed) vision embeddings
        params["patch_proj"] = dense_init(kp, cfg.d_model, cfg.d_model, dtype)
    return params


# ======================================================================
# forward (train / prefill)
# ======================================================================
def _uniform_block(x, lp, cfg, positions, window, collect_cache=False):
    aux = jnp.float32(0.0)
    kv = None
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if cfg.is_ssm_only:
        if collect_cache:
            o, kv = ssm_mod.ssm_forward(lp["ssm"], h, cfg, return_state=True)
        else:
            o = ssm_mod.ssm_forward(lp["ssm"], h, cfg)
        return x + o, aux, kv
    if collect_cache:
        o, kv = attn.attn_forward(lp["attn"], h, positions, cfg, causal=True,
                                  window=window, return_kv=True)
    else:
        o = attn.attn_forward(lp["attn"], h, positions, cfg, causal=True,
                              window=window)
    x = x + o
    if cfg.is_moe:
        y, aux = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["norm2"], x, cfg.norm_eps), cfg)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + mlp(lp["ffn"], rmsnorm(lp["norm2"], x, cfg.norm_eps))
    return x, aux, kv


def _hybrid_group_block(x, gp, cfg, positions, window, collect_cache=False):
    ae = cfg.attn_every
    aux = jnp.float32(0.0)
    take = lambda t, i: jax.tree.map(lambda a: a[i], t)
    attn_kv, ssm_states = None, []
    for pos in range(ae):
        n1, n2 = take(gp["norm1"], pos), take(gp["norm2"], pos)
        h = rmsnorm(n1, x, cfg.norm_eps)
        if pos == ae - 1:
            if collect_cache:
                o, attn_kv = attn.attn_forward(gp["attn"], h, positions, cfg,
                                               causal=True, window=window,
                                               return_kv=True)
            else:
                o = attn.attn_forward(gp["attn"], h, positions, cfg,
                                      causal=True, window=window)
            x = x + o
        else:
            if collect_cache:
                o, st = ssm_mod.ssm_forward(take(gp["ssm"], pos), h, cfg,
                                            return_state=True)
                ssm_states.append(st)
            else:
                o = ssm_mod.ssm_forward(take(gp["ssm"], pos), h, cfg)
            x = x + o
        hf = rmsnorm(n2, x, cfg.norm_eps)
        if pos % cfg.moe_every == cfg.moe_every - 1:
            y, lb = moe_mod.moe_apply(take(gp["moe"], pos // cfg.moe_every), hf, cfg)
            x, aux = x + y, aux + lb
        else:
            x = x + mlp(take(gp["ffn"], pos // cfg.moe_every), hf)
    kv = None
    if collect_cache:
        kv = {"attn": attn_kv,
              "ssm": jax.tree.map(lambda *a: jnp.stack(a), *ssm_states)}
    return x, aux, kv


def _run_stack(params, x, cfg, positions, window=0, collect_cache=False,
               remat=False):
    if cfg.is_hybrid:
        block = partial(_hybrid_group_block, cfg=cfg, positions=positions,
                        window=window, collect_cache=collect_cache)
    else:
        block = partial(_uniform_block, cfg=cfg, positions=positions,
                        window=window, collect_cache=collect_cache)
    if remat:
        # per-layer activation checkpointing: backward recomputes the block
        # (essential for flash attention, whose score blocks must not be saved)
        block = jax.checkpoint(block)

    def body(carry, lp):
        x, aux = carry
        x, lb, kv = block(x, lp)
        return (x, aux + lb), kv

    (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                    params["layers"])
    return x, aux, caches


def _encoder(params, frames, cfg):
    """frames: (B, S_enc, D) stubbed audio embeddings."""
    x = frames
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(x, lp):
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attn.attn_forward(lp["attn"], h, pos, cfg, causal=False)
        x = x + mlp(lp["ffn"], rmsnorm(lp["norm_ffn"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _decoder_encdec(params, tokens, enc_out, cfg):
    x = embed_lookup(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, lp):
        x = x + attn.attn_forward(lp["attn"], rmsnorm(lp["norm1"], x, cfg.norm_eps),
                                  pos, cfg, causal=True)
        x = x + attn.attn_forward(lp["xattn"], rmsnorm(lp["norm_x"], x, cfg.norm_eps),
                                  pos, cfg, enc_out=enc_out)
        x = x + mlp(lp["ffn"], rmsnorm(lp["norm_ffn"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return x


def forward(params, batch: Dict[str, jax.Array], cfg,
            window: int = 0, remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """batch: {tokens, [patches|frames]} -> (logits over token positions, aux)."""
    if cfg.is_encoder_decoder:
        enc_out = _encoder(params, batch["frames"], cfg)
        x = _decoder_encdec(params, batch["tokens"], enc_out, cfg)
        aux = jnp.float32(0.0)
    else:
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens)
        x = shard(x, "batch", "seq", "d_model")
        n_text = tokens.shape[1]
        if cfg.n_patches:
            pe = batch["patches"] @ params["patch_proj"]
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux, _ = _run_stack(params, x, cfg, positions, window, remat=remat)
        if cfg.n_patches:
            x = x[:, -n_text:, :]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(x, params["embed"] if cfg.tie_embeddings else None,
                     params.get("lm_head"))
    return logits, aux


def prefill(params, batch: Dict[str, jax.Array], cfg, window: int = 0
            ) -> Tuple[jax.Array, Any]:
    """Serve-side prefill: process the full prompt, return (last-position
    logits, layer-stacked KV/SSM cache) ready for ``decode_step``."""
    if cfg.is_encoder_decoder:
        raise NotImplementedError("use encdec_prefill for encoder-decoder")
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)
    x = shard(x, "batch", "seq", "d_model")
    if cfg.n_patches:
        pe = batch["patches"] @ params["patch_proj"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _, cache = _run_stack(params, x, cfg, positions, window,
                             collect_cache=True)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = lm_head(x, params["embed"] if cfg.tie_embeddings else None,
                     params.get("lm_head"))
    return logits, cache


def encdec_prefill(params, batch: Dict[str, jax.Array], cfg,
                   cache_len: int) -> Tuple[jax.Array, Any]:
    """Whisper-style prefill: run the encoder, fill cross KV caches, then
    teacher-force the prompt tokens through the decoder collecting self KV."""
    enc_out = _encoder(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        o, kv = attn.attn_forward(lp["attn"], rmsnorm(lp["norm1"], x, cfg.norm_eps),
                                  pos, cfg, causal=True, return_kv=True)
        x = x + o
        o, xkv = attn.attn_forward(lp["xattn"], rmsnorm(lp["norm_x"], x, cfg.norm_eps),
                                   pos, cfg, enc_out=enc_out, return_kv=True)
        x = x + o
        x = x + mlp(lp["ffn"], rmsnorm(lp["norm_ffn"], x, cfg.norm_eps))
        return x, {"k": kv["k"], "v": kv["v"], "xk": xkv["k"], "xv": xkv["v"]}

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = lm_head(x, params["embed"] if cfg.tie_embeddings else None,
                     params.get("lm_head"))
    return logits, cache


def lm_loss(params, batch, cfg, window: int = 0,
            lb_weight: float = 0.01, remat: bool = False,
            loss_chunk: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy.

    ``loss_chunk > 0`` computes the loss in sequence chunks WITHOUT ever
    materializing the full (B, S, vocab) f32 logits — each chunk's lm_head +
    softmax is rematerialized in the backward pass (memory-roofline lever for
    large-vocab archs; see EXPERIMENTS.md §Perf).
    """
    if loss_chunk <= 0:
        logits, aux = forward(params, batch, cfg, window, remat=remat)
        targets = batch["tokens"][:, 1:]
        logits = logits[:, :-1, :]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = nll.mean()
        return loss + lb_weight * aux, {"nll": loss, "lb": aux}

    # trunk without the head
    tokens = batch["tokens"]
    if cfg.is_encoder_decoder:
        enc_out = _encoder(params, batch["frames"], cfg)
        x = _decoder_encdec(params, tokens, enc_out, cfg)
        aux = jnp.float32(0.0)
    else:
        x = embed_lookup(params["embed"], tokens)
        x = shard(x, "batch", "seq", "d_model")
        if cfg.n_patches:
            pe = batch["patches"] @ params["patch_proj"]
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux, _ = _run_stack(params, x, cfg, positions, window, remat=remat)
        if cfg.n_patches:
            x = x[:, -tokens.shape[1]:, :]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    table = params["embed"] if cfg.tie_embeddings else None
    head = params.get("lm_head")
    B, S = tokens.shape
    Sm1 = S - 1
    C = min(loss_chunk, Sm1)
    n_chunks = -(-Sm1 // C)
    pad = n_chunks * C - Sm1

    xs = jnp.pad(x[:, :-1, :], ((0, 0), (0, pad), (0, 0)))
    tg = jnp.pad(tokens[:, 1:], ((0, 0), (0, pad)))
    valid = jnp.pad(jnp.ones((B, Sm1), jnp.float32), ((0, 0), (0, pad)))
    xs = xs.reshape(B, n_chunks, C, -1)
    tg = tg.reshape(B, n_chunks, C)
    valid = valid.reshape(B, n_chunks, C)

    @jax.checkpoint
    def chunk_nll(xc, tc, vc):
        logits = lm_head(xc, table, head)              # (B, C, V) f32
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * vc)

    def body(acc, inp):
        xc, tc, vc = inp
        return acc + chunk_nll(xc, tc, vc), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0),
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(tg, 1, 0),
         jnp.moveaxis(valid, 1, 0)))
    loss = total / (B * Sm1)
    return loss + lb_weight * aux, {"nll": loss, "lb": aux}


def extend_cache(cache, target_len: int):
    """Pad the sequence axis of attention KV caches (stacked layout
    (L, B, S, Hkv, hd)) out to ``target_len`` slots for continued decode."""

    def pad(path, a):
        name = None
        for p in path:
            if hasattr(p, "key"):
                name = str(p.key)
        if name in ("k", "v") and a.ndim == 5 and a.shape[2] < target_len:
            padw = [(0, 0)] * a.ndim
            padw[2] = (0, target_len - a.shape[2])
            return jnp.pad(a, padw)
        return a

    return jax.tree_util.tree_map_with_path(pad, cache)


# ======================================================================
# decode (one token with caches)
# ======================================================================
def init_decode_state(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16,
                      rolling: bool = False, quantized: bool = False):
    """Stacked (over layers / groups) cache pytree."""
    if cfg.is_encoder_decoder:
        one = attn.init_cache(cfg, batch, cache_len, dtype,
                              cross_len=cfg.enc_seq, quantized=quantized)
        return _stack_tree(one, cfg.n_layers)
    if cfg.is_hybrid:
        g = {
            "attn": attn.init_cache(cfg, batch, cache_len, dtype,
                                    quantized=quantized),
            "ssm": _stack_tree(ssm_mod.init_ssm_cache(cfg, batch, dtype),
                               cfg.attn_every - 1),
        }
        return _stack_tree(g, cfg.n_layers // cfg.attn_every)
    if cfg.is_ssm_only:
        return _stack_tree(ssm_mod.init_ssm_cache(cfg, batch, dtype), cfg.n_layers)
    return _stack_tree(attn.init_cache(cfg, batch, cache_len, dtype,
                                       quantized=quantized), cfg.n_layers)


def _stack_tree(tree, n: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)


def decode_step(params, tokens, pos, cfg, cache, *, rolling: bool = False,
                seq_shard_kv: bool = False) -> Tuple[jax.Array, Any]:
    """tokens: (B, 1) int32; pos: scalar int32 absolute position."""
    x = embed_lookup(params["embed"], tokens)
    x = shard(x, "batch", "seq", "d_model")
    aux = jnp.float32(0.0)

    if cfg.is_encoder_decoder:
        def body(x, xs):
            lp, lc = xs
            h, lc2 = attn.attn_decode(lp["attn"], rmsnorm(lp["norm1"], x, cfg.norm_eps),
                                      pos, cfg, lc, rolling=rolling)
            x = x + h
            h, _ = attn.attn_decode(lp["xattn"], rmsnorm(lp["norm_x"], x, cfg.norm_eps),
                                    pos, cfg, lc, cross=True)
            x = x + h
            x = x + mlp(lp["ffn"], rmsnorm(lp["norm_ffn"], x, cfg.norm_eps))
            return x, lc2

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.is_hybrid:
        def body(x, xs):
            gp, gc = xs
            take = lambda t, i: jax.tree.map(lambda a: a[i], t)
            new_ssm = []
            ae = cfg.attn_every
            for p_ in range(ae):
                h = rmsnorm(take(gp["norm1"], p_), x, cfg.norm_eps)
                if p_ == ae - 1:
                    o, ac = attn.attn_decode(gp["attn"], h, pos, cfg, gc["attn"],
                                             rolling=rolling)
                    x = x + o
                else:
                    o, sc = ssm_mod.ssm_decode(take(gp["ssm"], p_), h, cfg,
                                               take(gc["ssm"], p_))
                    new_ssm.append(sc)
                    x = x + o
                hf = rmsnorm(take(gp["norm2"], p_), x, cfg.norm_eps)
                if p_ % cfg.moe_every == cfg.moe_every - 1:
                    y, _ = moe_mod.moe_apply(take(gp["moe"], p_ // cfg.moe_every), hf, cfg)
                    x = x + y
                else:
                    x = x + mlp(take(gp["ffn"], p_ // cfg.moe_every), hf)
            stacked_ssm = jax.tree.map(lambda *a: jnp.stack(a), *new_ssm)
            return x, {"attn": ac, "ssm": stacked_ssm}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.is_ssm_only:
        def body(x, xs):
            lp, lc = xs
            h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
            o, lc2 = ssm_mod.ssm_decode(lp["ssm"], h, cfg, lc)
            return x + o, lc2

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        def body(carry, xs):
            x, aux = carry
            lp, lc = xs
            h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
            if seq_shard_kv:
                o, lc2 = attn.attn_decode_seqshard(lp["attn"], h, pos, cfg, lc)
            else:
                o, lc2 = attn.attn_decode(lp["attn"], h, pos, cfg, lc,
                                          rolling=rolling)
            x = x + o
            if cfg.is_moe:
                y, lb = moe_mod.moe_apply(lp["moe"], rmsnorm(lp["norm2"], x, cfg.norm_eps), cfg)
                x, aux = x + y, aux + lb
            elif cfg.d_ff > 0:
                x = x + mlp(lp["ffn"], rmsnorm(lp["norm2"], x, cfg.norm_eps))
            return (x, aux), lc2

        (x, aux), new_cache = jax.lax.scan(body, (x, aux), (params["layers"], cache))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(x, params["embed"] if cfg.tie_embeddings else None,
                     params.get("lm_head"))
    return logits, new_cache
