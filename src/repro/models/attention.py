"""GQA attention: train/prefill (flash-chunked), encoder (full), cross, decode.

Pure JAX. Query-chunked + kv-chunked online-softmax attention keeps live
memory bounded at 32k sequence lengths; causal chunk skipping is structural
(python loop over query chunks, inner ``lax.scan`` only over needed kv chunks)
so the compiled FLOPs match causal attention, not dense.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, head_rmsnorm, rotary
from repro.sharding.rules import shard, shard_map

NEG_INF = -1e30


def attn_init(key, cfg, dtype=jnp.float32, cross: bool = False):
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_q(p, x, positions, cfg, rope: bool):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if "q_norm" in p:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if rope:
        q = rotary(q, positions, cfg.rope_theta)
    return shard(q, "batch", "seq", "heads", "head_dim")


def _project_kv(p, x, positions, cfg, rope: bool):
    B, S, _ = x.shape
    hd = cfg.head_dim
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if "k_norm" in p:
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        k = rotary(k, positions, cfg.rope_theta)
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return k, v


def _grouped_scores(q, k):
    """q: (B,Sq,H,hd), k: (B,Sk,G,hd) with H = G*rep -> (B,G,rep,Sq,Sk) f32.

    Operands keep their storage dtype (bf16 on TPU) with f32 MXU
    accumulation — converting the KV cache to f32 before the dot would
    double its HBM read traffic (§Perf pair-B iteration 2).
    """
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    q = q.reshape(B, Sq, G, H // G, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q, k,
                   preferred_element_type=jnp.float32)
    return s / math.sqrt(hd)


def _grouped_out(probs, v, out_dtype):
    """probs: (B,G,rep,Sq,Sk), v: (B,Sk,G,hd) -> (B,Sq,H,hd)."""
    B, G, rep, Sq, _ = probs.shape
    o = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, G * rep, -1).astype(out_dtype)


def _plain_attention(q, k, v, mask) -> jax.Array:
    s = _grouped_scores(q, k)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)  # mask broadcasts over (B,G,rep)
    probs = jax.nn.softmax(s, axis=-1)
    return _grouped_out(probs, v, q.dtype)


def _flash_attention(q, k, v, *, causal: bool, window: int = 0,
                     q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, memory O(q_chunk * kv_chunk) scores."""
    B, Sq, H, hd = q.shape
    Sk, G = k.shape[1], k.shape[2]
    rep = H // G
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad ragged sequence lengths (e.g. VLM: patches + tokens) up to chunks
    Sq_pad = -(-Sq // q_chunk) * q_chunk
    Sk_pad = -(-Sk // kv_chunk) * kv_chunk
    if Sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    if Sk_pad != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)))
    Sk_real, Sq_orig = Sk, Sq
    Sq, Sk = Sq_pad, Sk_pad
    n_q = Sq // q_chunk

    def one_q_chunk(qi: int, qc):
        # kv chunks needed for this q chunk (structural causal skip)
        q_end = (qi + 1) * q_chunk if causal else Sk
        n_kv = -(-q_end // kv_chunk)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = _grouped_scores(qc, kc)                   # (B,G,rep,qc,kc)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = jnp.broadcast_to((k_pos < Sk_real)[None, :],
                                   (q_chunk, kv_chunk))
            if causal:
                msk = msk & (q_pos[:, None] >= k_pos[None, :])
            if window:
                msk = msk & ((q_pos[:, None] - k_pos[None, :]) < window)
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, G, rep, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(n_kv))
        o = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,G,rep,qc,hd)
        return jnp.moveaxis(o, 3, 1).reshape(B, q_chunk, H, hd).astype(q.dtype)

    outs = []
    for qi in range(n_q):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
        outs.append(one_q_chunk(qi, qc))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out[:, :Sq_orig] if Sq_orig != Sq else out


# ----------------------------------------------------------------------
def attn_forward(p, x, positions, cfg, *, causal: bool = True,
                 enc_out=None, window: int = 0,
                 flash_threshold: int = 2048, return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, _ = x.shape
    rope = enc_out is None
    q = _project_q(p, x, positions, cfg, rope)
    if enc_out is None:
        k, v = _project_kv(p, x, positions, cfg, rope)
    else:
        Se = enc_out.shape[1]
        k, v = _project_kv(p, enc_out, jnp.zeros((B, Se), jnp.int32), cfg, False)

    Sk = k.shape[1]
    if max(S, Sk) > flash_threshold:
        o = _flash_attention(q, k, v, causal=causal and enc_out is None,
                             window=window)
    else:
        mask = None
        if causal and enc_out is None:
            mask = jnp.tril(jnp.ones((S, Sk), bool))
            if window:
                mask &= (jnp.arange(S)[:, None] - jnp.arange(Sk)[None, :]) < window
        o = _plain_attention(q, k, v, mask)
    o = shard(o, "batch", "seq", "heads", "head_dim")
    o = o.reshape(B, S, -1) @ p["wo"]
    o = shard(o, "batch", "seq", "d_model")
    if return_kv:
        return o, {"k": k, "v": v}
    return o


# -- decode (one token, KV cache) ---------------------------------------
def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16,
               cross_len: int = 0, quantized: bool = False):
    """KV cache. ``quantized=True`` stores int8 levels + per-(slot, head)
    f32 scales — the paper's quantization insight applied to serving memory
    (2x HBM traffic cut at decode; see EXPERIMENTS.md §Perf)."""
    hd = cfg.head_dim
    G = cfg.n_kv_heads
    if quantized:
        c = {
            "k": jnp.zeros((batch, cache_len, G, hd), jnp.int8),
            "v": jnp.zeros((batch, cache_len, G, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, G), jnp.float32),
            "v_scale": jnp.zeros((batch, cache_len, G), jnp.float32),
        }
    else:
        c = {
            "k": jnp.zeros((batch, cache_len, G, hd), dtype),
            "v": jnp.zeros((batch, cache_len, G, hd), dtype),
        }
    if cross_len:
        c["xk"] = jnp.zeros((batch, cross_len, G, hd), dtype)
        c["xv"] = jnp.zeros((batch, cross_len, G, hd), dtype)
    return c


def _quant_kv(x):
    """x: (B,1,G,hd) -> (int8 levels, (B,1,G) scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-12)
    lv = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None] * 127),
                  -127, 127).astype(jnp.int8)
    return lv, scale


def _dequant_kv(lv, scale, dtype):
    return (lv.astype(jnp.float32) * (scale[..., None] / 127.0)).astype(dtype)


def attn_decode(p, x, pos, cfg, cache, *, rolling: bool = False,
                cross: bool = False) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B,1,D); pos: scalar absolute position.

    ``rolling=True`` treats the cache as a circular window buffer (slot =
    pos % cache_len, all slots valid) for sub-quadratic long-context decode.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _project_q(p, x, positions, cfg, rope=not cross)

    if cross:  # enc-dec cross attention: cache is pre-filled, never written
        k, v = cache["xk"], cache["xv"]
        mask = None
        new_cache = cache
    else:
        k_new, v_new = _project_kv(p, x, positions, cfg, rope=True)
        L = cache["k"].shape[1]
        slot = jnp.mod(pos, L) if rolling else pos
        quantized = "k_scale" in cache
        if quantized:
            k_lv, k_sc = _quant_kv(k_new)
            v_lv, v_sc = _quant_kv(v_new)
            upd = jax.lax.dynamic_update_slice_in_dim
            kq = upd(cache["k"], k_lv, slot, 1)
            vq = upd(cache["v"], v_lv, slot, 1)
            ks = upd(cache["k_scale"], k_sc, slot, 1)
            vs = upd(cache["v_scale"], v_sc, slot, 1)
            new_cache = dict(cache, k=kq, v=vq, k_scale=ks, v_scale=vs)
            k = _dequant_kv(kq, ks, x.dtype)
            v = _dequant_kv(vq, vs, x.dtype)
        else:
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
            new_cache = dict(cache, k=k, v=v)
        if rolling:
            valid = jnp.minimum(pos + 1, L)  # warmup: only first pos+1 slots
            mask = (jnp.arange(L) < valid)[None, :]
        else:
            mask = (jnp.arange(L) <= pos)[None, :]

    s = _grouped_scores(q, k)                       # (B,G,rep,1,L)
    if mask is not None:
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    o = _grouped_out(probs, v, x.dtype)             # (B,1,H,hd)
    o = o.reshape(B, 1, -1) @ p["wo"]
    return shard(o, "batch", "seq", "d_model"), new_cache


# -- sequence-sharded decode (beyond-paper: MQA/GQA KV too small to TP) ---
def attn_decode_seqshard(p, x, pos, cfg, cache) -> Tuple[jax.Array, dict]:
    """One-token decode with the KV cache sharded along SEQUENCE over the
    'model' axis, merged with a log-sum-exp flash-merge psum.

    For MQA (granite: kv=1) the KV cache cannot shard over heads, so every
    TP rank otherwise reads the full 32k cache.  Sharding the cache on the
    sequence axis cuts per-chip KV HBM traffic by the TP degree at the cost
    of one tiny (B,H) psum triple.  See EXPERIMENTS.md §Perf.
    """
    from repro.sharding.rules import active_rules
    from jax.sharding import PartitionSpec as P
    rules = active_rules()
    mesh = rules.mesh
    n_model = mesh.shape["model"]
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _project_q(p, x, positions, cfg, rope=True)        # (B,1,H,hd)
    k_new, v_new = _project_kv(p, x, positions, cfg, rope=True)

    L = cache["k"].shape[1]
    L_loc = L // n_model
    ba = rules.mapping.get("batch")
    batch_axes = (ba,) if isinstance(ba, str) else tuple(ba or ())
    bspec = batch_axes if (batch_axes and B % (
        math.prod(mesh.shape[a] for a in batch_axes)) == 0) else None

    cache_spec = P(bspec, "model", None, None)

    def body(q_r, kn, vn, kc, vc):
        r = jax.lax.axis_index("model")
        # write the new kv into the owner rank's slice
        slot_loc = pos - r * L_loc
        owned = (slot_loc >= 0) & (slot_loc < L_loc)
        slot_c = jnp.clip(slot_loc, 0, L_loc - 1)
        kc2 = jax.lax.dynamic_update_slice_in_dim(
            kc, kn.astype(kc.dtype), slot_c, 1)
        vc2 = jax.lax.dynamic_update_slice_in_dim(
            vc, vn.astype(vc.dtype), slot_c, 1)
        kc2 = jnp.where(owned, kc2, kc)
        vc2 = jnp.where(owned, vc2, vc)

        s = _grouped_scores(q_r, kc2)                      # (B,G,rep,1,L_loc)
        gidx = r * L_loc + jnp.arange(L_loc)
        s = jnp.where((gidx <= pos)[None, None, None, None, :], s, NEG_INF)
        m_loc = s.max(axis=-1)                             # (B,G,rep,1)
        m_glob = jax.lax.pmax(m_loc, "model")
        e = jnp.exp(s - m_glob[..., None])
        l_loc = e.sum(axis=-1)
        o_loc = jnp.einsum("bgrqk,bkgd->bgrqd", e.astype(vc2.dtype), vc2,
                           preferred_element_type=jnp.float32)
        l = jax.lax.psum(l_loc, "model")
        o = jax.lax.psum(o_loc, "model")
        o = (o / jnp.maximum(l, 1e-30)[..., None])
        Bq, G, rep, _, hd = o.shape
        o = jnp.moveaxis(o, 3, 1).reshape(Bq, 1, G * rep, hd)
        return o.astype(q_r.dtype), kc2, vc2

    o, k2, v2 = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None), cache_spec, cache_spec),
        out_specs=(P(bspec, None, None, None), cache_spec, cache_spec),
        check_vma=False)(q, k_new, v_new, cache["k"], cache["v"])
    new_cache = dict(cache, k=k2, v=v2)
    o = o.reshape(B, 1, -1) @ p["wo"]
    return shard(o, "batch", "seq", "d_model"), new_cache
