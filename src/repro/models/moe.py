"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths with identical routing semantics:

* ``_moe_dense_ref`` — reference: every expert computed on every token, masked
  combine. Exact (no capacity drops). Used on CPU/no-mesh and as the oracle.
* ``_moe_ep_sharded`` — production: ``shard_map`` over the mesh; each model
  rank owns ``E/model`` experts, selects up to capacity C tokens per expert
  from its (data-sharded, model-replicated) token slice via a sort-free
  cumsum-rank dispatch, runs the expert FFN locally, scatter-adds weighted
  outputs and ``psum``s over the model axis.  The only collective is that
  psum — token->expert transport is free because activations enter the block
  model-replicated (Megatron-TP style).

Top-k routing: softmax over the top-k router logits (Mixtral convention).
Aux output is the Switch-style load-balance loss.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.rules import active_rules, shard, shard_map


def moe_init(key, cfg, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(k1, d, E, jnp.float32),
        "e_gate": jax.random.uniform(k2, (E, d, f), dtype, -scale, scale),
        "e_up": jax.random.uniform(k3, (E, d, f), dtype, -scale, scale),
        "e_down": jax.random.uniform(k4, (E, f, d), dtype,
                                     -1.0 / math.sqrt(f), 1.0 / math.sqrt(f)),
    }


def _route(router, x, k: int):
    """x: (T, D) -> (weights (T,k) f32, experts (T,k) i32, probs (T,E) f32)."""
    logits = (x.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, top_e = jax.lax.top_k(logits, k)
    top_w = jax.nn.softmax(top_logits, axis=-1)
    return top_w, top_e, probs


def _load_balance_loss(probs, top_e, n_experts: int) -> jax.Array:
    """Switch-transformer aux loss: E * sum_e f_e * p_e."""
    onehot = jax.nn.one_hot(top_e, n_experts, dtype=jnp.float32)  # (T,k,E)
    frac = onehot.sum(axis=(0, 1)) / (top_e.shape[0] * top_e.shape[1])
    mean_p = probs.mean(axis=0)
    return n_experts * jnp.sum(frac * mean_p)


def _expert_ffn(gate, up, down, xb):
    """xb: (E?, C, D) with per-expert weights (E?, D, F)/(E?, F, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, up)
    return jnp.einsum("ecf,efd->ecd", h, down)


def _moe_dense_ref(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    T, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    w, e, probs = _route(params["router"], x, k)
    # compute every expert on every token (reference only)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, params["e_gate"]))
    h = h * jnp.einsum("td,edf->etf", x, params["e_up"])
    y_e = jnp.einsum("etf,efd->etd", h, params["e_down"])      # (E,T,D)
    onehot = jax.nn.one_hot(e, E, dtype=y_e.dtype)             # (T,k,E)
    comb = jnp.einsum("tke,tk->et", onehot, w.astype(y_e.dtype))
    y = jnp.einsum("etd,et->td", y_e, comb)
    return y, _load_balance_loss(probs, e, E)


def _dispatch_ranks(top_e, E: int):
    """Sort-free rank-within-expert for each (token, slot). Returns (S,) i32
    rank and (S,) i32 flat expert id, S = T*k."""
    fe = top_e.reshape(-1)                                     # (S,)
    onehot = (fe[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - 1                     # (S,E)
    rank = jnp.take_along_axis(ranks, fe[:, None], axis=1)[:, 0]
    return rank, fe


def _moe_ep_local(params_loc, x_loc, cfg, capacity: int, e_loc: int,
                  model_axis: str):
    """shard_map body: x_loc (T_loc, D) model-replicated; expert weights local."""
    T, D = x_loc.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    r = jax.lax.axis_index(model_axis)
    w, e, probs = _route(params_loc["router"], x_loc, k)
    rank, fe = _dispatch_ranks(e, E)
    fw = w.reshape(-1)
    tok = jnp.arange(T * k) // k

    le = fe - r * e_loc
    owned = (le >= 0) & (le < e_loc) & (rank < capacity)
    dest = jnp.where(owned, le * capacity + rank, e_loc * capacity)  # OOB slot

    nbuf = e_loc * capacity
    buf = jnp.zeros((nbuf + 1, D), x_loc.dtype).at[dest].set(x_loc[tok])
    tok_idx = jnp.full((nbuf + 1,), T, jnp.int32).at[dest].set(tok.astype(jnp.int32))
    w_buf = jnp.zeros((nbuf + 1,), jnp.float32).at[dest].set(fw)

    xb = buf[:nbuf].reshape(e_loc, capacity, D)
    yb = _expert_ffn(params_loc["e_gate"], params_loc["e_up"],
                     params_loc["e_down"], xb).reshape(nbuf, D)
    contrib = yb * w_buf[:nbuf, None].astype(yb.dtype)
    y = jnp.zeros((T, D), x_loc.dtype).at[tok_idx[:nbuf]].add(
        contrib.astype(x_loc.dtype), mode="drop")
    y = jax.lax.psum(y, model_axis)
    aux = _load_balance_loss(probs, e, E)
    return y, aux


def moe_apply(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y (B,S,D), load_balance_loss scalar)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    rules = active_rules()
    if rules is None or "model" not in rules.mesh.axis_names \
            or cfg.n_experts % rules.mesh.shape["model"] != 0:
        y, aux = _moe_dense_ref(params, xt, cfg)
        return shard(y.reshape(B, S, D), "batch", "seq", "d_model"), aux

    mesh = rules.mesh
    n_model = mesh.shape["model"]
    e_loc = cfg.n_experts // n_model
    # token sharding follows the *current* logical 'batch' mapping (inside
    # the fed group-local region this is None: the fed axes hold the groups)
    ba = rules.mapping.get("batch")
    batch_axes = (ba,) if isinstance(ba, str) else tuple(ba or ())
    n_batch = math.prod(mesh.shape[a] for a in batch_axes) if batch_axes else 1
    T = B * S
    tok_spec = batch_axes if batch_axes and T % n_batch == 0 else None
    T_loc = T // n_batch if tok_spec else T
    capacity = max(8, int(math.ceil(T_loc * cfg.moe_top_k / cfg.n_experts
                                    * cfg.capacity_factor)))

    from jax.sharding import PartitionSpec as P
    in_specs = (
        {"router": P(), "e_gate": P("model"), "e_up": P("model"),
         "e_down": P("model")},
        P(tok_spec, None),
    )
    out_specs = (P(tok_spec, None), P())

    def body(p_loc, x_loc):
        y, aux = _moe_ep_local(p_loc, x_loc, cfg, capacity, e_loc, "model")
        # aux differs per data shard; average to a replicated scalar
        axes = batch_axes if tok_spec else ()
        if axes:
            aux = jax.lax.pmean(aux, axes)
        return y, aux

    y, aux = shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)(params, xt)
    return shard(y.reshape(B, S, D), "batch", "seq", "d_model"), aux
