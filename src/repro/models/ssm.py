"""Mamba2 (SSD — state-space duality) block, pure JAX reference path.

Chunked SSD: within a chunk the recurrence is unrolled into a masked
quadratic (attention-like) form; across chunks a ``lax.scan`` carries the
(H, P, N) state.  ``kernels/ssd_scan.py`` provides the Pallas TPU kernel for
the intra-chunk part; this module is the oracle and the dry-run path.

Decode is the O(1) recurrence: h = a*h + dt*B⊗x ; y = C·h + D*x.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, rmsnorm_init
from repro.sharding.rules import shard


def ssm_init(key, cfg, dtype=jnp.float32):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * n + h          # z, x, B, C, dt
    scale = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.uniform(k1, (d, d_in_proj), dtype, -scale, scale),
        "conv_w": jax.random.uniform(k2, (w, di + 2 * n), dtype, -0.5, 0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "ssm_d": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_proj": jax.random.uniform(
            k3, (di, d), dtype, -1.0 / math.sqrt(di), 1.0 / math.sqrt(di)),
        "gate_norm": rmsnorm_init(di, dtype),
    }


def _split_proj(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(u, w):
    """u: (B,S,C), w: (W,C) — per-channel causal conv via shifted adds."""
    W = w.shape[0]
    out = u * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(u[:, :-i, :], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[W - 1 - i]
    return out


def _ssd_inputs(params, proj, cfg, conv_fn=_causal_conv):
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = jax.nn.silu(conv_fn(xbc, params["conv_w"]))
    x = xbc[..., :di]
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    B_, S_ = x.shape[0], x.shape[1]
    xh = x.reshape(B_, S_, h, p)
    la = -jnp.exp(params["a_log"]) * dt                                 # (B,S,H) log decay
    return z, xh, b, c, dt, la


def ssd_chunked(xh, b, c, dt, la, chunk: int,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. xh (B,S,H,P), b/c (B,S,N), dt/la (B,S,H).
    Returns y (B,S,H,P) and final state (B,H,P,N)."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L

    xb = (xh * dt[..., None]).reshape(B, nc, L, H, P).astype(jnp.float32)
    bc_ = b.reshape(B, nc, L, N).astype(jnp.float32)
    cc_ = c.reshape(B, nc, L, N).astype(jnp.float32)
    lac = la.reshape(B, nc, L, H)
    cum = jnp.cumsum(lac, axis=2)                          # (B,nc,L,H)

    # intra-chunk (quadratic within chunk).  Mask the EXPONENT, not the
    # exponential: upper-triangular entries have positive log-decay and
    # exp() overflows to inf, which poisons gradients (inf * 0 = nan in vjp).
    cb = jnp.einsum("bcln,bcmn->bclm", cc_, bc_)           # (B,nc,L,L)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    m = jnp.exp(diff)
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", cb, m, xb)

    # chunk states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,L,H)
    s_c = jnp.einsum("bcln,bclh,bclhp->bchpn", bc_, decay_to_end, xb)
    a_chunk = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_body(hprev, inputs):
        s_ci, a_ci = inputs
        hnew = a_ci[:, :, None, None] * hprev + s_ci
        return hnew, hprev

    hfin, hprevs = jax.lax.scan(
        scan_body, h0,
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                    # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", cc_, jnp.exp(cum), hprevs)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(xh.dtype), hfin


def ssm_forward(params, x, cfg, init_state=None, return_state: bool = False):
    """Full-sequence Mamba2 mixer. x: (B,S,D) -> (B,S,D)."""
    proj = x @ params["in_proj"]
    di, n = cfg.d_inner, cfg.ssm_state
    z, xh, b, c, dt, la = _ssd_inputs(params, proj, cfg)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    y, state = ssd_chunked(xh, b, c, dt, la, cfg.ssm_chunk, init_state)
    y = y + (params["ssm_d"][:, None]
             * (xh.astype(jnp.float32) * dt[..., None])).astype(y.dtype)
    B_, S_ = x.shape[0], x.shape[1]
    y = y.reshape(B_, S_, cfg.d_inner)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = shard(y @ params["out_proj"], "batch", "seq", "d_model")
    if return_state:
        w = cfg.ssm_conv_width
        xbc_raw = proj[..., di:di + di + 2 * n]
        tail = xbc_raw[:, -(w - 1):, :]
        pad = w - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"state": state, "conv": tail.astype(x.dtype)}
    return out


# -- decode -------------------------------------------------------------
def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def ssm_decode(params, x, cfg, cache):
    """One-token recurrence. x: (B,1,D)."""
    B = x.shape[0]
    proj = x @ params["in_proj"]                            # (B,1,*)

    def conv_step(u, w):
        # u: (B,1,C); cache["conv"]: (B,W-1,C)
        hist = jnp.concatenate([cache["conv"], u], axis=1)  # (B,W,C)
        out = jnp.einsum("bwc,wc->bc", hist, w)[:, None, :]
        return out, hist[:, 1:, :]

    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(proj, cfg)
    conv_out, new_conv = conv_step(xbc, params["conv_w"])
    xbc = jax.nn.silu(conv_out)
    xv = xbc[..., :di].reshape(B, h, p)
    b = xbc[..., di:di + n][:, 0, :]                        # (B,N)
    c = xbc[..., di + n:][:, 0, :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0, :]  # (B,H)
    a = jnp.exp(-jnp.exp(params["a_log"]) * dt)             # (B,H)

    xbar = xv.astype(jnp.float32) * dt[..., None]           # (B,H,P)
    new_state = (a[:, :, None, None] * cache["state"]
                 + jnp.einsum("bhp,bn->bhpn", xbar, b.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", new_state, c.astype(jnp.float32))
    y = y + params["ssm_d"][:, None] * xbar
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], {"state": new_state, "conv": new_conv}
