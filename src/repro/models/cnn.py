"""The paper's Fashion-MNIST CNN (TEASQ-Fed §5.1).

"two 2x2 convolutional layers, a fully connected layer, and a softmax
output" — conv(2x2,32) + pool, conv(2x2,32) + pool, fc(128), fc(10).
~206k float32 params ≈ 0.8 MB, matching Table 7's 794.66 KB model size.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


def init_cnn(key, n_classes: int = 10, channels: int = 32,
             fc_width: int = 128) -> Dict[str, jax.Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv_init(k, h, w, cin, cout):
        scale = 1.0 / math.sqrt(h * w * cin)
        return jax.random.uniform(k, (h, w, cin, cout), jnp.float32,
                                  -scale, scale)

    flat = 7 * 7 * channels
    return {
        "conv1": conv_init(k1, 2, 2, 1, channels),
        "b1": jnp.zeros((channels,)),
        "conv2": conv_init(k2, 2, 2, channels, channels),
        "b2": jnp.zeros((channels,)),
        "fc1": jax.random.uniform(k3, (flat, fc_width), jnp.float32,
                                  -1.0 / math.sqrt(flat), 1.0 / math.sqrt(flat)),
        "bf1": jnp.zeros((fc_width,)),
        "fc2": jax.random.uniform(k4, (fc_width, n_classes), jnp.float32,
                                  -1.0 / math.sqrt(fc_width), 1.0 / math.sqrt(fc_width)),
        "bf2": jnp.zeros((n_classes,)),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def cnn_features(params, images: jax.Array) -> jax.Array:
    """Penultimate representation (used by the MOON baseline's contrastive
    term)."""
    x = jax.nn.relu(_conv(images, params["conv1"], params["b1"]))
    x = _pool(x)
    x = jax.nn.relu(_conv(x, params["conv2"], params["b2"]))
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(x @ params["fc1"] + params["bf1"])


def cnn_forward(params, images: jax.Array) -> jax.Array:
    """images: (B, 28, 28, 1) -> logits (B, 10)."""
    return cnn_features(params, images) @ params["fc2"] + params["bf2"]


def cnn_loss(params, batch) -> jax.Array:
    logits = cnn_forward(params, batch["images"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1).mean()


def cnn_accuracy(params, images, labels) -> jax.Array:
    return (cnn_forward(params, images).argmax(-1) == labels).mean()


# ----------------------------------------------------------------------
# Cohort (vectorized multi-device) formulation
# ----------------------------------------------------------------------
# ``jax.vmap`` of ``cnn_forward`` over per-device weights lowers the convs to
# grouped convolutions, which XLA:CPU executes ~8x slower than the serial
# loop.  The cohort forward instead im2col's the 2x2 convs into batched
# einsums (one (C, pix, k) x (C, k, out) matmul per layer), which is bitwise
# identical to ``cnn_forward`` per device and lowers to fast batched GEMMs.

def _patches2x2(x: jax.Array) -> jax.Array:
    """(C, B, H, W, F) -> (C, B, H, W, 4F): 2x2 patches under XLA's SAME
    padding for an even kernel (pad low 0, high 1)."""
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 1), (0, 0)))
    return jnp.concatenate([xp[:, :, :-1, :-1], xp[:, :, :-1, 1:],
                            xp[:, :, 1:, :-1], xp[:, :, 1:, 1:]], axis=-1)


def _pool2(x: jax.Array) -> jax.Array:
    c, b, h, w, f = x.shape
    return x.reshape(c, b, h // 2, 2, w // 2, 2, f).max(axis=(3, 5))


def _conv2x2_cohort(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (C, B, H, W, Fin); w: (C, 2, 2, Fin, Fout) -> (C, B, H, W, Fout)."""
    p = _patches2x2(x)
    wk = w.reshape(w.shape[0], 4 * w.shape[3], w.shape[4])
    return jnp.einsum("cbhwk,cko->cbhwo", p, wk) + b[:, None, None, None, :]


def cnn_cohort_features(params, images: jax.Array) -> jax.Array:
    """Per-device-weights features: params leaves carry a leading cohort axis
    C; images are (C, B, 28, 28, 1)."""
    x = jax.nn.relu(_conv2x2_cohort(images, params["conv1"], params["b1"]))
    x = _pool2(x)
    x = jax.nn.relu(_conv2x2_cohort(x, params["conv2"], params["b2"]))
    x = _pool2(x)
    x = x.reshape(x.shape[0], x.shape[1], -1)
    return jax.nn.relu(jnp.einsum("cbk,cko->cbo", x, params["fc1"])
                       + params["bf1"][:, None, :])


def cnn_cohort_forward(params, images: jax.Array) -> jax.Array:
    """(C, B, 28, 28, 1) -> logits (C, B, 10) with per-device weights."""
    h = cnn_cohort_features(params, images)
    return (jnp.einsum("cbk,cko->cbo", h, params["fc2"])
            + params["bf2"][:, None, :])


def cnn_cohort_loss(params, images: jax.Array, labels: jax.Array) -> jax.Array:
    logits = cnn_cohort_forward(params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
