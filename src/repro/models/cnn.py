"""The paper's Fashion-MNIST CNN (TEASQ-Fed §5.1).

"two 2x2 convolutional layers, a fully connected layer, and a softmax
output" — conv(2x2,32) + pool, conv(2x2,32) + pool, fc(128), fc(10).
~206k float32 params ≈ 0.8 MB, matching Table 7's 794.66 KB model size.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


def init_cnn(key, n_classes: int = 10, channels: int = 32,
             fc_width: int = 128) -> Dict[str, jax.Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv_init(k, h, w, cin, cout):
        scale = 1.0 / math.sqrt(h * w * cin)
        return jax.random.uniform(k, (h, w, cin, cout), jnp.float32,
                                  -scale, scale)

    flat = 7 * 7 * channels
    return {
        "conv1": conv_init(k1, 2, 2, 1, channels),
        "b1": jnp.zeros((channels,)),
        "conv2": conv_init(k2, 2, 2, channels, channels),
        "b2": jnp.zeros((channels,)),
        "fc1": jax.random.uniform(k3, (flat, fc_width), jnp.float32,
                                  -1.0 / math.sqrt(flat), 1.0 / math.sqrt(flat)),
        "bf1": jnp.zeros((fc_width,)),
        "fc2": jax.random.uniform(k4, (fc_width, n_classes), jnp.float32,
                                  -1.0 / math.sqrt(fc_width), 1.0 / math.sqrt(fc_width)),
        "bf2": jnp.zeros((n_classes,)),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def cnn_features(params, images: jax.Array) -> jax.Array:
    """Penultimate representation (used by the MOON baseline's contrastive
    term)."""
    x = jax.nn.relu(_conv(images, params["conv1"], params["b1"]))
    x = _pool(x)
    x = jax.nn.relu(_conv(x, params["conv2"], params["b2"]))
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(x @ params["fc1"] + params["bf1"])


def cnn_forward(params, images: jax.Array) -> jax.Array:
    """images: (B, 28, 28, 1) -> logits (B, 10)."""
    return cnn_features(params, images) @ params["fc2"] + params["bf2"]


def cnn_loss(params, batch) -> jax.Array:
    logits = cnn_forward(params, batch["images"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1).mean()


def cnn_accuracy(params, images, labels) -> jax.Array:
    return (cnn_forward(params, images).argmax(-1) == labels).mean()
