"""Event-driven asynchronous FL simulator (virtual wall-clock).

Faithfully executes the TEASQ-Fed protocol of Fig. 1 over N devices with the
paper's wireless + shifted-exponential latency model, running *real* JAX
local training (prox-SGD on the model selected by ``SimConfig.task`` — the
Fashion-MNIST-like CNN by default; see ``repro.fl.tasks.TASKS``).  Also
drives the baselines: FedAvg (synchronous), FedAsync (immediate update),
TEA-Fed (no compression), TEAS/TEAQ/TEAStatic/TEASQ (compression variants).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import local_update
from repro.core.codecs import IdentityCodec
from repro.core.dynamic import CompressionSchedule
from repro.core.latency import ComputeConfig, WirelessConfig
from repro.core.server import ServerConfig, TeasqServer
from repro.core.staleness import staleness_weight
from repro.fl.tasks import get_task


@functools.partial(jax.jit, static_argnames=("lr", "mu_con", "tau",
                                             "forward_fn", "features_fn"))
def _moon_sgd_step(params, batch, lr: float, mu_con: float, tau: float,
                   forward_fn, features_fn):
    """MOON (Li et al., CVPR'21) local step: CE + model-contrastive loss
    pulling representations toward the global model and away from the
    device's previous local model.  ``forward_fn``/``features_fn`` come from
    the bound :class:`repro.fl.tasks.FLTask` (static: stable function
    attributes, so re-resolving a task reuses the jit cache)."""

    def loss_fn(p):
        logits = forward_fn(p, batch["images"])
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, batch["labels"][:, None], 1).mean()
        z = features_fn(p, batch["images"])
        zg = features_fn(batch["glob"], batch["images"])
        zp = features_fn(batch["prev"], batch["images"])

        def cos(a, b):
            return (a * b).sum(-1) / (jnp.linalg.norm(a, axis=-1)
                                      * jnp.linalg.norm(b, axis=-1) + 1e-8)

        sim_g = cos(z, zg) / tau
        sim_p = cos(z, zp) / tau
        lcon = -(sim_g - jnp.logaddexp(sim_g, sim_p)).mean()
        return ce + mu_con * lcon

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return jax.tree.map(lambda p, g: p - lr * g, params, grads), loss


def moon_local_train(w_glob: Any, prev: Any, x, y, *, epochs: int,
                     batch_size: int, lr: float, rng: np.random.RandomState,
                     forward_fn: Callable, features_fn: Callable) -> Any:
    """MOON device-side update: E epochs of `_moon_sgd_step` minibatches.
    Shared by the legacy simulator and the engine's MoonStrategy so the two
    backends cannot drift apart.  Callers pass the bound task's
    ``forward``/``features`` (MOON needs a representation head; tasks
    without one cannot run this baseline)."""
    if forward_fn is None or features_fn is None:
        raise ValueError(
            "MOON's model-contrastive term needs the task's forward and "
            "features heads (FLTask.forward / FLTask.features)")
    params = w_glob
    for _ in range(epochs):
        order = rng.permutation(len(y))
        for s in range(0, len(y) - batch_size + 1, batch_size):
            sel = order[s:s + batch_size]
            batch = {"images": jnp.asarray(x[sel]),
                     "labels": jnp.asarray(y[sel]),
                     "glob": w_glob, "prev": prev}
            params, _ = _moon_sgd_step(params, batch, lr,
                                       mu_con=1.0, tau=0.5,
                                       forward_fn=forward_fn,
                                       features_fn=features_fn)
    return params


@dataclasses.dataclass
class TierSpec:
    """One heterogeneity tier: a fraction of the fleet with scaled compute
    speed (multiplies the shifted-exponential coefficient a_k; >1 = slower)
    and scaled link bandwidth (multiplies both directions' rates;
    <1 = slower links)."""
    fraction: float
    compute_scale: float = 1.0
    bandwidth_scale: float = 1.0
    name: str = ""


def tier_assignment(n_devices: int,
                    tiers: Optional[List[TierSpec]]) -> np.ndarray:
    """Contiguous deterministic tier indices by device id: tier ``i`` covers
    the next ``round(fraction_i * n)`` devices and the last tier absorbs the
    remainder.  Shared by ``DeviceRegistry.apply_tiers`` (latency scaling)
    and the codec policies (``repro.fl.policies``), so the latency model and
    per-device codec choice always agree on who sits in which tier."""
    tier = np.zeros(n_devices, np.int64)
    if not tiers:
        return tier
    start = 0
    for i, t in enumerate(tiers):
        stop = n_devices if i == len(tiers) - 1 else min(
            n_devices, start + int(round(t.fraction * n_devices)))
        tier[start:stop] = i
        start = stop
    return tier


@dataclasses.dataclass
class ScenarioConfig:
    """Scenario-injection knobs.  ``FLEngine`` consumes all of them; the
    legacy ``FLSimulator`` applies only ``tiers`` (latency scaling + the
    tier-aware codec policies) and ignores the failure knobs.  All
    randomness is drawn from a dedicated scenario RNG so that an all-zero
    ScenarioConfig leaves the engine's event stream bit-identical to the
    no-scenario run.

    * ``dropout_prob``: per-task probability the device leaves the fleet
      mid-round (permanent); its slot is freed and re-dispatched.
      Engine-only.
    * ``failure_prob``: per-task probability of a transient mid-round crash;
      the device retries after ``retry_backoff`` simulated seconds.
      Engine-only.
    * ``retry_backoff``: simulated seconds before a transiently-failed
      device re-requests work.
    * ``tiers``: heterogeneous compute/bandwidth ``TierSpec`` tiers assigned
      contiguously by device index according to each tier's ``fraction``
      (see ``tier_assignment``); also the tier structure the ``tier_aware``
      codec policy adapts to.
    """
    dropout_prob: float = 0.0
    failure_prob: float = 0.0
    retry_backoff: float = 1.0
    tiers: Optional[List[TierSpec]] = None

    @property
    def active(self) -> bool:
        return (self.dropout_prob > 0.0 or self.failure_prob > 0.0
                or bool(self.tiers))


@dataclasses.dataclass
class SimConfig:
    """One config object for both simulator backends — every knob, in one
    place (the README's configuration table is generated from this list):

    **Protocol & model**

    * ``method`` — protocol name from ``repro.fl.protocols.STRATEGIES``:
      the TEA-Fed family (``tea`` uncompressed, ``teas`` sparsify-only,
      ``teaq`` quantize-only, ``teastatic`` both static, ``teasq`` the full
      Alg. 5 schedule), async baselines (``fedasync``, ``port``,
      ``asofed``), and synchronous baselines (``fedavg``, ``moon``).
    * ``task`` — model family under training, from ``repro.fl.tasks.TASKS``
      (``fmnist_cnn`` = the paper's §5.1 CNN; ``transformer_lm``,
      ``fmnist_mlp`` — any registered FLTask trains under any protocol).
    * ``n_devices`` — fleet size N.

    **Server (Algs. 1-2)**

    * ``c_fraction`` — admission gate: at most ``ceil(N * C)`` devices train
      concurrently (Alg. 1).
    * ``gamma`` — aggregation cache fraction: a round completes after
      ``ceil(N * gamma)`` uploads (Alg. 2, Eq. 6).
    * ``alpha`` — server mixing rate of the cached aggregate (Eq. 10); also
      the async baselines' base mixing weight.
    * ``a`` — staleness-decay exponent (Eq. 9).
    * ``max_staleness`` — FedAsync staleness cap in its poly decay.

    **Device-side local training (Alg. 1, Eq. 5)**

    * ``mu`` — proximal term weight; ``epochs``/``batch_size``/``lr`` — the
      local prox-SGD loop.
    * ``devices_per_round`` — synchronous (FedAvg/MOON) cohort size.

    **Wire compression (Algs. 3-5)**

    * ``p_s`` — kept fraction under Top-K sparsification (1.0 = keep all).
    * ``p_q`` — quantization bit width (32 = no quantization).
    * ``schedule`` — optional Alg. 5 decay ``CompressionSchedule``;
      overrides the static point for ``teasq``.
    * ``codec`` — wire codec family (``repro.core.codecs.CODECS``):
      ``dense`` = the Algs. 3-4 reference codec, ``packed`` = the real
      bit-packed stream (docs/WIRE_FORMAT.md), ``threshold`` = the in-graph
      approximate channel, ``identity`` = compression off.  The
      uncompressed (p_s>=1, p_q>=32) point short-circuits to identity for
      every family.
    * ``codec_policy`` — per-device codec policy
      (``repro.fl.policies.POLICIES``): ``static`` (default — the
      protocol's own global operating point, byte-identical to the
      pre-policy behavior), ``tier_aware`` (slower-bandwidth tiers get more
      aggressive points, from ``tier_points`` or log2-derived notches), or
      ``staleness_aware`` (chronically stale devices get extra compression
      notches).
    * ``tier_points`` — optional explicit per-tier ``(p_s, p_q)`` list for
      the ``tier_aware`` policy, e.g. the output of the per-tier Alg. 5
      search ``profile_compression(..., tiers=...)``; index i maps to
      ``scenario.tiers[i]``.

    **Latency model (§3.1)**

    * ``wireless`` — cell geometry/power (``WirelessConfig``).
    * ``compute`` — shifted-exponential compute latency (``ComputeConfig``).

    **Infrastructure**

    * ``seed`` — the single RNG seed behind data, latency draws, and
      protocol randomness (fixed seed = bit-reproducible history).
    * ``scheduler`` — engine-only event-loop implementation: ``"heap"``
      (the reference one-event-at-a-time ``heapq`` loop) or ``"batched"``
      (``repro.fl.engine.BatchedEngine`` — resident per-device next-event
      arrays with vectorized batch selection; bit-identical histories, an
      order of magnitude cheaper per task at 10^4-10^5 devices).  The
      legacy ``FLSimulator`` ignores it.
    * ``cohort_size`` — engine-only: > 0 switches ``FLEngine`` to the
      vectorized cohort trainer (deferred training, one jitted call per
      padded cohort); the legacy ``FLSimulator`` ignores it.
    * ``cohort_channel_iters`` — threshold binary-search iterations of the
      in-graph channel the cohort path fuses.
    * ``handler_mode`` — batched-scheduler-only event *processing* mode:
      ``"serial"`` (default) falls each selected event through the scalar
      ``FLEngine`` handlers — bit-identical to the heap scheduler and
      pinned against ``tests/data/pinned_histories.json``.  ``"wave"``
      processes maximal same-kind event runs as arrays (vectorized Alg. 1
      admission gate, one ``DeviceRegistry.round_latency_batch`` draw per
      grant wave, fused Eqs. 6-10 arrival aggregation) under a documented
      *relaxed* parity contract: the same protocol decisions in the same
      event order, but RNG draws batched per wave and assigned in
      device-index order rather than heap-pop order, aggregation reduced
      via a stacked kernel, and same-``now`` drains applied once per wave.
      See the ``repro.fl.engine`` module docstring for the exact contract.
      Requires ``scheduler="batched"``; the heap scheduler rejects it.
    * ``server`` — engine-only server backend from
      ``repro.core.server.SERVERS``: ``"single"`` (default —
      ``TeasqServer``, the bit-pinned single-host reference) or
      ``"sharded"`` (``ShardedTeasqServer`` — the Eqs. 6-10 cache
      reduction runs as a ``shard_map`` over a 1-D mesh of local devices,
      e.g. host devices under
      ``XLA_FLAGS=--xla_force_host_platform_device_count=N``; on a
      single-device process it degenerates to the exact ``"single"``
      path).  The legacy ``FLSimulator`` ignores it.
    * ``server_shards`` — mesh width cap for ``server="sharded"``
      (0 = use every local device).
    * ``scenario`` — ``ScenarioConfig`` injection (dropout / transient
      failure / heterogeneity tiers); see its docstring for which backend
      consumes what.
    """

    method: str = "teasq"
    task: str = "fmnist_cnn"
    n_devices: int = 100
    c_fraction: float = 0.1
    gamma: float = 0.1
    alpha: float = 0.6
    a: float = 0.5
    mu: float = 0.01
    epochs: int = 2
    batch_size: int = 40
    lr: float = 0.08
    # compression (used by teas/teaq/teastatic/teasq)
    p_s: float = 1.0
    p_q: int = 32
    schedule: Optional[CompressionSchedule] = None
    codec: str = "dense"
    # per-device adaptive codec policy (repro.fl.policies.POLICIES)
    codec_policy: str = "static"
    tier_points: Optional[List[Tuple[float, int]]] = None
    # latency model
    wireless: WirelessConfig = dataclasses.field(default_factory=WirelessConfig)
    compute: ComputeConfig = dataclasses.field(default_factory=ComputeConfig)
    # fedavg / fedasync
    devices_per_round: int = 10
    max_staleness: int = 4
    seed: int = 0
    # engine-only knobs; see class docstring
    scheduler: str = "heap"
    cohort_size: int = 0
    cohort_channel_iters: int = 12   # threshold binary-search iterations
    handler_mode: str = "serial"     # "serial" | "wave" (batched only)
    server: str = "single"           # repro.core.server.SERVERS backend
    server_shards: int = 0           # sharded-server mesh width (0 = all)
    scenario: Optional[ScenarioConfig] = None


@dataclasses.dataclass
class LogEntry:
    time: float
    round: int
    accuracy: float
    bytes_up: int
    bytes_down: int
    max_model_bytes_up: int
    max_model_bytes_down: int


class FLSimulator:
    def __init__(self, data: Dict[str, np.ndarray],
                 partitions: List[np.ndarray], w_init: Any, cfg: SimConfig):
        self.cfg = cfg
        self.data = data
        self.partitions = partitions
        self.rng = np.random.RandomState(cfg.seed)
        n = cfg.n_devices
        assert len(partitions) == n
        # the engine's DeviceRegistry draws rates then a_k in exactly this
        # simulator's historical order, so sharing it keeps bit-parity while
        # giving the legacy backend the same tier scaling (lazy import:
        # engine imports us)
        from repro.fl.engine import DeviceRegistry
        self.devices = DeviceRegistry(cfg, self.rng)
        if cfg.scenario is not None and cfg.scenario.tiers:
            self.devices.apply_tiers(cfg.scenario.tiers)
        self.server = TeasqServer(w_init, ServerConfig(
            n, cfg.c_fraction, cfg.gamma, cfg.alpha, cfg.a))
        self.bytes_up = 0
        self.bytes_down = 0
        self.max_up = 0
        self.max_down = 0
        self.prev_local: Dict[int, Any] = {}   # MOON: per-device prev model
        self.task = get_task(cfg.task)
        self._eval = jax.jit(self.task.eval_metric)
        self.history: List[LogEntry] = []
        # the codec seam is shared with the engine: the bound strategy's
        # channel_for(t, device_id) answers "which wire codec does a round-t
        # dispatch to device k use" for both simulators (lazy import:
        # protocols imports us)
        from repro.fl.protocols import make_strategy
        self.strategy = make_strategy(cfg.method, cfg)

    # ------------------------------------------------------------------
    def _train_device(self, k: int, w: Any) -> Tuple[Any, int]:
        idx = self.partitions[k]
        x, y = self.data["x_train"][idx], self.data["y_train"][idx]
        if self.cfg.method == "moon":
            return self._train_device_moon(k, w, x, y), len(idx)
        w_new, _, steps = local_update(
            w, x, y, self.task.loss, epochs=self.cfg.epochs,
            batch_size=self.cfg.batch_size, lr=self.cfg.lr, mu=self.cfg.mu,
            rng=self.rng)
        return w_new, len(idx)

    def _train_device_moon(self, k: int, w_glob: Any, x, y) -> Any:
        prev = self.prev_local.get(k, w_glob)
        params = moon_local_train(w_glob, prev, x, y, epochs=self.cfg.epochs,
                                  batch_size=self.cfg.batch_size,
                                  lr=self.cfg.lr, rng=self.rng,
                                  forward_fn=self.task.forward,
                                  features_fn=self.task.features)
        self.prev_local[k] = params
        return params

    def _round_latency(self, k: int, bits_down: float, bits_up: float,
                       n_batches: int) -> Tuple[float, float, float]:
        return self.devices.round_latency(k, bits_down, bits_up, n_batches,
                                          self.rng)

    def evaluate(self) -> float:
        xs, ys = self.data["x_test"], self.data["y_test"]
        accs = []
        for s in range(0, len(ys), 2000):
            accs.append(float(self._eval(self.server.w,
                                         jnp.asarray(xs[s:s + 2000]),
                                         jnp.asarray(ys[s:s + 2000]))))
        return float(np.mean(accs))

    def _log(self, time: float):
        self.history.append(LogEntry(
            time, self.server.t, self.evaluate(), self.bytes_up,
            self.bytes_down, self.max_up, self.max_down))

    # ------------------------------------------------------------------
    def run(self, time_budget: float = 300.0, max_rounds: int = 10 ** 9,
            eval_every: int = 1) -> List[LogEntry]:
        if self.cfg.method in ("fedavg", "moon"):
            return self._run_fedavg(time_budget, max_rounds, eval_every)
        return self._run_async(time_budget, max_rounds, eval_every)

    def _async_alpha(self, staleness: int) -> float:
        """Per-method immediate-update mixing weight (async baselines)."""
        cfg = self.cfg
        if cfg.method == "port":       # unbounded staleness, harder decay
            return cfg.alpha * (staleness + 1.0) ** -1.0
        if cfg.method == "asofed":     # linear decay
            return cfg.alpha / (1.0 + staleness)
        stale = min(staleness, cfg.max_staleness)   # fedasync: capped poly
        return cfg.alpha * float(staleness_weight(stale, cfg.a))

    # -- asynchronous protocols (teasq family + fedasync) ----------------
    def _run_async(self, time_budget: float, max_rounds: int,
                   eval_every: int) -> List[LogEntry]:
        cfg = self.cfg
        events: List[Tuple[float, int, str, int, Any, int]] = []
        seq = 0

        def push(t, kind, k, payload=None, h=0):
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, k, payload, h))
            seq += 1

        waiting: List[int] = []
        for k in range(cfg.n_devices):
            push(self.rng.uniform(0, 0.05), "request", k)

        self._log(0.0)
        fedasync = cfg.method in ("fedasync", "port", "asofed")

        now = 0.0   # the heap can be empty (n_devices=0) or the first pop
        while events:  # can exceed time_budget; the final log still needs now
            now, _, kind, k, payload, h = heapq.heappop(events)
            if now > time_budget or self.server.t >= max_rounds:
                break
            if kind == "request":
                grant = self.server.try_dispatch()
                if grant is None:
                    waiting.append(k)
                    continue
                w_t, t0 = grant
                codec = self.strategy.channel_for(t0, device_id=k)
                w_recv, nbytes_down = codec.roundtrip(w_t, rng=self.rng)
                self.bytes_down += nbytes_down
                self.max_down = max(self.max_down, nbytes_down)
                w_local, n_k = self._train_device(k, w_recv)
                w_up, nbytes_up = codec.roundtrip(w_local, rng=self.rng)
                self.bytes_up += nbytes_up
                self.max_up = max(self.max_up, nbytes_up)
                n_batches = max(1, n_k // cfg.batch_size)
                dl, cp, ul = self._round_latency(
                    k, nbytes_down * 8, nbytes_up * 8, n_batches)
                push(now + dl + cp + ul, "arrival", k, (w_up, n_k), t0)
            else:  # arrival
                w_local, n_k = payload
                # feed the codec policy's per-device staleness estimator
                # (no-op for the static policy; draws no RNG)
                self.strategy.policy.observe_arrival(
                    k, max(0, self.server.t - h))
                if fedasync:
                    self.server.active = max(0, self.server.active - 1)
                    a_t = self._async_alpha(self.server.t - h)
                    self.server.w = jax.tree.map(
                        lambda wl, wg: a_t * wl + (1 - a_t) * wg,
                        w_local, self.server.w)
                    self.server.t += 1
                    done_round = True
                else:
                    done_round = self.server.receive(w_local, h, n_k)
                if done_round and self.server.t % eval_every == 0:
                    self._log(now)
                push(now, "request", k)
                # FIFO-equivalent to re-pushing the whole queue, without the
                # O(waiting) event churn per freed slot
                free = self.server.cfg.max_parallel - self.server.active
                for _ in range(min(free, len(waiting))):
                    push(now, "request", waiting.pop(0))
        self._log(min(now, time_budget))
        return self.history

    # -- synchronous FedAvg ----------------------------------------------
    def _run_fedavg(self, time_budget: float, max_rounds: int,
                    eval_every: int) -> List[LogEntry]:
        cfg = self.cfg
        now = 0.0
        self._log(now)
        per_round = min(cfg.devices_per_round, cfg.n_devices)
        identity = IdentityCodec()       # FedAvg/MOON ship dense f32
        while now < time_budget and self.server.t < max_rounds:
            sel = self.rng.choice(cfg.n_devices, per_round, replace=False)
            updates, weights, latencies = [], [], []
            for k in sel:
                nbytes = identity.wire_bytes(self.server.w)
                self.bytes_down += nbytes
                self.max_down = max(self.max_down, nbytes)
                w_local, n_k = self._train_device(k, self.server.w)
                self.bytes_up += nbytes
                self.max_up = max(self.max_up, nbytes)
                n_batches = max(1, n_k // cfg.batch_size)
                dl, cp, ul = self._round_latency(k, nbytes * 8, nbytes * 8,
                                                 n_batches)
                latencies.append(dl + cp + ul)
                updates.append(w_local)
                weights.append(n_k)
            wts = np.asarray(weights, np.float32)
            wts /= wts.sum()
            self.server.w = jax.tree.map(
                lambda *ls: sum(w * l for w, l in zip(wts, ls)), *updates)
            self.server.t += 1
            now += max(latencies)        # straggler-bound synchronous round
            if self.server.t % eval_every == 0:
                self._log(now)
        return self.history
