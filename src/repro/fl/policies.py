"""Adaptive per-device codec policies: who gets which wire format.

The paper's Alg. 5 picks ONE global ``(p_s, p_q)`` operating point for the
whole fleet.  On a heterogeneous fleet that is the wrong trade everywhere at
once: fast links pay accuracy for compression they do not need, while slow
links stall on bytes they cannot afford (TimelyFL, arXiv:2304.06947, makes
the per-device-adaptation case; SEAFL, arXiv:2503.05755, the
staleness-adaptive one).  A :class:`CodecPolicy` closes that gap — it maps
the *dispatch context* (round ``t``, device id, the device's
bandwidth/compute tier from ``ScenarioConfig.tiers``, and a per-device
staleness estimate fed by both simulator backends) to a concrete
:class:`~repro.core.codecs.Codec` at a per-device ``(p_s, p_q)`` operating
point.

Wiring: ``SimConfig.codec_policy`` selects a policy from :data:`POLICIES`;
``ProtocolStrategy.__init__`` binds it and ``channel_for(t, device_id)``
routes every dispatch through :meth:`CodecPolicy.codec_for`, so both
``FLEngine`` and the legacy ``FLSimulator`` meter exact per-device wire
bytes through whatever codec the policy picked.  Registered policies:

* ``static`` — the protocol's own global operating point, untouched.  This
  is the default and is byte-identical to the pre-policy behavior (pinned
  by tests/test_policies.py against tests/data/pinned_histories.json).
* ``tier_aware`` — per-bandwidth-tier operating points: explicit
  ``SimConfig.tier_points`` (e.g. from the per-tier Alg. 5 search
  ``profile_compression(..., tiers=...)``), or, when unset, derived by
  stepping the base point ``round(log2(1 / bandwidth_scale))`` notches
  toward more compression along the Alg. 5 candidate sets — so a tier with
  1/8 the bandwidth ships ~3 notches more aggressively packed updates while
  full-rate tiers stay at the protocol's near-dense point.
* ``staleness_aware`` — the server down-weights stale uploads
  (Eq. 9), so wire bits spent on chronically stale devices buy little
  aggregation mass; devices whose EWMA staleness crosses successive
  ``stale_per_notch`` thresholds get extra compression notches.

Policies only adapt *compressing* dispatches: a protocol whose base point is
uncompressed (TEA-Fed, FedAvg, FedAsync) keeps dense f32 wire semantics
under every policy.  A new policy is one subclass + one :data:`POLICIES`
entry.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import ClassVar, Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.codecs import Codec, resolve_codec
from repro.core.compression import FLOAT_BITS
from repro.core.dynamic import DEFAULT_SET_Q, DEFAULT_SET_S
from repro.fl.simulator import SimConfig, tier_assignment


@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """Everything a policy may condition on for one round-``t`` dispatch."""
    t: int
    device_id: Optional[int]
    tier: int                  # index into ScenarioConfig.tiers (0 if none)
    bandwidth_scale: float     # the tier's link scaling (<1 = slower)
    compute_scale: float       # the tier's compute scaling (>1 = slower)
    staleness: float           # EWMA of the device's observed staleness


def _nearest_idx(candidates: Sequence, x) -> int:
    return min(range(len(candidates)), key=lambda i: abs(candidates[i] - x))


def notch_point(p_s: float, p_q: int, notches: int,
                set_s: Sequence[float] = DEFAULT_SET_S,
                set_q: Sequence[int] = DEFAULT_SET_Q) -> Tuple[float, int]:
    """Step an operating point ``notches`` steps toward more compression
    along the Alg. 5 candidate sets (clamped at the most compressed entry).
    ``notches=0`` snaps to the nearest candidate pair without moving."""
    si = min(_nearest_idx(set_s, p_s) + notches, len(set_s) - 1)
    qi = min(_nearest_idx(set_q, p_q) + notches, len(set_q) - 1)
    return set_s[si], set_q[qi]


class CodecPolicy(abc.ABC):
    """Maps a dispatch context to a codec + ``(p_s, p_q)`` operating point.

    Hooks:

    * :meth:`codec_for` — the strategy-facing entry point; adapts only
      compressing dispatches and binds the (possibly per-device) point to
      the configured ``SimConfig.codec`` family.
    * :meth:`operating_point` — the policy decision itself; override this.
    * :meth:`observe_arrival` / :meth:`observe_arrivals` — fed when
      uploads land, with each arrival's staleness in aggregation rounds;
      the base class keeps a per-device EWMA, updated through one
      vectorized scatter (the scalar hook is a singleton group of the
      batched one, so every engine shares one numeric path).  Draws no
      RNG, so inactive policies leave event streams bit-identical.
    """

    name: ClassVar[str] = ""
    staleness_beta: ClassVar[float] = 0.5     # EWMA update weight

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        tiers = cfg.scenario.tiers if cfg.scenario is not None else None
        self.tiers = list(tiers) if tiers else []
        self.tier_of = tier_assignment(cfg.n_devices, tiers)
        self.bandwidth_scale = np.asarray(
            [t.bandwidth_scale for t in self.tiers] or [1.0])
        self.compute_scale = np.asarray(
            [t.compute_scale for t in self.tiers] or [1.0])
        self.staleness_est = np.zeros(cfg.n_devices)

    def _known(self, device_id: Optional[int]) -> bool:
        # device ids beyond cfg.n_devices (a strategy reused across fleets)
        # fall back to tier-0 / fresh rather than indexing out of range
        return device_id is not None and 0 <= device_id < len(self.tier_of)

    def observe_arrival(self, device_id: int, staleness: float) -> None:
        self.observe_arrivals([device_id], [staleness])

    def observe_arrivals(self, device_ids, staleness) -> None:
        """Vectorized EWMA scatter over a group of arrivals — the batched
        hook ``BatchedEngine`` feeds (the heap path routes its per-event
        ``observe_arrival`` through the same code, so the two schedulers
        share one numeric path).  Unknown device ids are dropped, exactly
        like the scalar hook.  EWMA updates to *different* devices commute,
        so a unique-id group is one fused scatter; repeated ids within a
        group fall back to in-order scalar updates (per-device EWMA steps
        do not commute)."""
        ids = np.asarray(device_ids, np.int64)
        st = np.asarray(staleness, np.float64)
        ok = (ids >= 0) & (ids < len(self.tier_of))
        if not ok.all():
            ids, st = ids[ok], st[ok]
        if not len(ids):
            return
        b = self.staleness_beta
        est = self.staleness_est
        if len(ids) == 1 or len(np.unique(ids)) == len(ids):
            est[ids] = (1.0 - b) * est[ids] + b * st
        else:
            for i, s in zip(ids.tolist(), st.tolist()):
                est[i] = (1.0 - b) * est[i] + b * s

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Checkpointable policy state: the per-device staleness EWMAs (all
        the mutable state any registered policy keeps)."""
        return {"staleness_est": np.asarray(self.staleness_est)}

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        self.staleness_est[:] = np.asarray(state["staleness_est"])

    def context(self, t: int, device_id: Optional[int]) -> DispatchContext:
        known = self._known(device_id)
        tier = int(self.tier_of[device_id]) if known else 0
        stale = float(self.staleness_est[device_id]) if known else 0.0
        return DispatchContext(t, device_id, tier,
                               float(self.bandwidth_scale[tier]),
                               float(self.compute_scale[tier]), stale)

    @abc.abstractmethod
    def operating_point(self, ctx: DispatchContext, p_s: float,
                        p_q: int) -> Tuple[float, int]:
        """The adapted ``(p_s, p_q)`` for this dispatch, given the
        protocol's base point."""

    def codec_for(self, t: int, device_id: Optional[int], p_s: float,
                  p_q: int) -> Codec:
        if p_s < 1.0 or p_q < FLOAT_BITS:   # only adapt compressing rounds
            p_s, p_q = self.operating_point(self.context(t, device_id),
                                            p_s, p_q)
        return resolve_codec(self.cfg.codec, p_s, p_q,
                             iters=self.cfg.cohort_channel_iters)

    def codecs_for(self, t: int, device_ids, p_s: float,
                   p_q: int) -> list:
        """Vectorized :meth:`codec_for` over a grant wave.  Default: the
        scalar hook per device (correct for any policy); subclasses whose
        operating point depends on less than the full per-device context
        override it to resolve once per distinct point."""
        return [self.codec_for(t, int(k), p_s, p_q) for k in device_ids]


class StaticPolicy(CodecPolicy):
    """The protocol's own global Alg. 5 point for every device — the
    default, byte-identical to the pre-policy codec resolution."""

    name = "static"

    def observe_arrival(self, device_id, staleness) -> None:
        pass                                  # keeps the hot path trivial

    def observe_arrivals(self, device_ids, staleness) -> None:
        pass

    def operating_point(self, ctx, p_s, p_q):
        return p_s, p_q

    def codec_for(self, t, device_id, p_s, p_q) -> Codec:
        return resolve_codec(self.cfg.codec, p_s, p_q,
                             iters=self.cfg.cohort_channel_iters)

    def codecs_for(self, t, device_ids, p_s, p_q) -> list:
        # one resolve, shared instance across the wave (codecs are frozen)
        codec = self.codec_for(t, None, p_s, p_q)
        return [codec] * len(device_ids)


class TierAwarePolicy(CodecPolicy):
    """Bandwidth-tier-aware compression (the TimelyFL-style heterogeneity
    adaptation): each tier gets its own operating point.  Explicit
    ``SimConfig.tier_points`` (index i = ``scenario.tiers[i]``) win — feed
    them from the per-tier Alg. 5 search, ``profile_compression(...,
    tiers=cfg.scenario.tiers)``.  Without them, the point is derived by
    stepping the protocol's base point ``round(log2(1 / bandwidth_scale))``
    notches toward more compression, so a fleet with no tiers (or an
    all-full-rate one) is indistinguishable from ``static``."""

    name = "tier_aware"

    def operating_point(self, ctx, p_s, p_q):
        points = self.cfg.tier_points
        if points:
            p_s, p_q = points[min(ctx.tier, len(points) - 1)]
            return float(p_s), int(p_q)
        b = max(ctx.bandwidth_scale, 1e-9)
        notches = max(0, int(round(np.log2(1.0 / b))))
        return notch_point(p_s, p_q, notches) if notches else (p_s, p_q)

    def codecs_for(self, t, device_ids, p_s, p_q) -> list:
        """The tier-aware point only reads the device's tier, so a wave
        resolves once per *distinct tier present* instead of per device."""
        if not (p_s < 1.0 or p_q < FLOAT_BITS):
            codec = resolve_codec(self.cfg.codec, p_s, p_q,
                                  iters=self.cfg.cohort_channel_iters)
            return [codec] * len(device_ids)
        ids = np.asarray(device_ids, np.int64)
        known = (ids >= 0) & (ids < len(self.tier_of))
        tiers = np.where(known,
                         self.tier_of[np.clip(ids, 0,
                                              len(self.tier_of) - 1)], 0)
        out: list = [None] * len(ids)
        for tier in np.unique(tiers).tolist():
            ctx = DispatchContext(t, None, tier,
                                  float(self.bandwidth_scale[tier]),
                                  float(self.compute_scale[tier]), 0.0)
            ps_t, pq_t = self.operating_point(ctx, p_s, p_q)
            codec = resolve_codec(self.cfg.codec, ps_t, pq_t,
                                  iters=self.cfg.cohort_channel_iters)
            for i in np.flatnonzero(tiers == tier).tolist():
                out[i] = codec
        return out


class StalenessAwarePolicy(CodecPolicy):
    """Staleness-adaptive compression (the SEAFL-style treatment of slow
    uploads): Eq. 9 down-weights an update by its staleness, so the wire
    bits of a chronically stale device buy less aggregation mass than the
    same bits from a fresh one.  Devices whose EWMA staleness crosses
    successive ``stale_per_notch`` thresholds ship ``1..max_notches`` extra
    compression notches; fresh devices keep the protocol's base point."""

    name = "staleness_aware"
    stale_per_notch: ClassVar[float] = 2.0   # EWMA rounds per extra notch
    max_notches: ClassVar[int] = 2

    def operating_point(self, ctx, p_s, p_q):
        notches = min(self.max_notches,
                      int(ctx.staleness // self.stale_per_notch))
        return notch_point(p_s, p_q, notches) if notches else (p_s, p_q)


POLICIES: Dict[str, Type[CodecPolicy]] = {
    cls.name: cls for cls in (StaticPolicy, TierAwarePolicy,
                              StalenessAwarePolicy)
}


def make_policy(name: str, cfg: SimConfig) -> CodecPolicy:
    try:
        return POLICIES[name](cfg)
    except KeyError:
        raise ValueError(f"unknown codec policy {name!r}; "
                         f"expected one of {sorted(POLICIES)}") from None
