"""Protocol strategies + one-call drivers for each method in the paper's §5.

The strategy interface is the pluggable seam of the FL engine
(``repro.fl.engine.FLEngine``): each protocol is a small class that answers
three questions — what wire codec does a round-``t`` dispatch use
(``channel_for``: a ``repro.core.codecs.Codec`` bound to the round's
Algs. 3-4 operating point), how does a device train locally (Alg. 1 device
side), and what happens when an update arrives at the server (Alg. 2 for the
TEA family, immediate mixing for the async baselines, the straggler-bound
synchronous loop for FedAvg/MOON).  ``make_strategy`` resolves a method name
from ``METHODS`` to a bound instance; registering a new protocol is one
subclass plus one registry entry.
"""
from __future__ import annotations

import abc
from typing import Any, ClassVar, Dict, List, Optional, Tuple, Type

import jax
import numpy as np

from repro.core.codecs import Codec, resolve_codec
from repro.core.dynamic import (DEFAULT_SET_Q, DEFAULT_SET_S, greedy_search,
                                greedy_search_per_tier)
from repro.core.staleness import staleness_weight
from repro.data.synthetic import partition_iid, partition_noniid_classes
from repro.fl.policies import make_policy
from repro.fl.simulator import (FLSimulator, LogEntry, SimConfig,
                                moon_local_train)
from repro.fl.tasks import get_task

METHODS = ("fedavg", "fedasync", "tea", "teas", "teaq", "teastatic",
           "teasq", "moon", "port", "asofed")


# ----------------------------------------------------------------------
# Strategy interface
# ----------------------------------------------------------------------
class ProtocolStrategy(abc.ABC):
    """One FL protocol, bound to a SimConfig.  Engine hooks:

    * ``channel_for(t, device_id=None)`` — the wire
      :class:`~repro.core.codecs.Codec` for a task dispatched at round t to
      device ``device_id`` (both directions); engines meter bytes via
      ``codec.wire_bytes`` and apply loss via ``codec.roundtrip``.  The
      protocol's global (p_s, p_q) point is routed through the bound
      :class:`~repro.fl.policies.CodecPolicy` (``SimConfig.codec_policy``):
      ``static`` keeps it as-is for every device, ``tier_aware`` /
      ``staleness_aware`` adapt it per device.
    * ``compression_at(t)`` — the protocol's *global* (p_s, p_q) operating
      point (Alg. 5 schedule or static point); protocols override this
      one-liner and ``channel_for`` hands it to the policy, which binds the
      final point to the ``SimConfig.codec`` family.
    * ``local_train(engine, k, w)`` — device-side update; defaults to the
      engine's trainer (serial prox-SGD or vectorized cohort).
    * ``on_arrival(engine, now, k, payload, h)`` — server-side handling of a
      completed upload; returns True when an aggregation round finished.
    * ``aggregate(engine, updates, weights)`` — synchronous-round merge
      (only used when ``event_driven`` is False).
    """

    method: ClassVar[str] = ""
    event_driven: ClassVar[bool] = True
    # True when on_arrivals fuses a whole arrival wave without needing the
    # per-event round bookkeeping of the serial handler — the wave engine
    # (SimConfig.handler_mode="wave") routes arrival runs through the fused
    # path only for strategies that declare it; everyone else keeps the
    # bit-faithful scalar fallback.
    arrival_wave: ClassVar[bool] = False

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.policy = make_policy(cfg.codec_policy, cfg)

    def compression_at(self, t: int) -> Tuple[float, int]:
        return 1.0, 32

    def channel_for(self, t: int, device_id: Optional[int] = None) -> Codec:
        """Codec for a round-``t`` dispatch to ``device_id``: the strategy's
        global (p_s, p_q) point, adapted per device by the bound
        :class:`~repro.fl.policies.CodecPolicy` and bound to the configured
        codec family (``SimConfig.codec``).  ``device_id`` defaults to None
        for backward compatibility (one-arg callers get the tier-0 /
        fresh-device point); strategy subclasses may still override this
        hook directly for bespoke per-device behavior."""
        p_s, p_q = self.compression_at(t)
        return self.policy.codec_for(t, device_id, p_s, p_q)

    def local_train(self, engine, k: int, w: Any) -> Tuple[Any, int]:
        return engine.trainer.train(k, w)

    def on_arrival(self, engine, now: float, k: int, payload: Any,
                   h: int) -> bool:
        raise NotImplementedError(
            f"{self.method} is not an event-driven protocol")

    # -- batched hooks (BatchedEngine) ----------------------------------
    # The batched scheduler talks to strategies through group-shaped hooks;
    # both default to the serial hooks item-by-item, which is what keeps
    # the batched engine bit-identical to the heap engine.  A protocol that
    # tolerates coarser interleaving (no per-arrival eval logging between
    # group members) can override them to fuse work across a group — e.g.
    # one fused Eqs. 6-10 cache update for a burst of same-time arrivals.

    def channels_for(self, t: int, device_ids) -> List[Codec]:
        """Batched grant hook: the wire codec for each device of a round-
        ``t`` dispatch group.  When the strategy uses the stock
        ``channel_for`` the group resolves through the policy's vectorized
        ``codecs_for`` (one resolve per distinct operating point — what
        makes million-device grant waves cheap); a strategy that overrides
        ``channel_for`` for bespoke per-device behavior keeps the per-device
        loop so its override still sees every dispatch."""
        if type(self).channel_for is ProtocolStrategy.channel_for:
            p_s, p_q = self.compression_at(t)
            return self.policy.codecs_for(t, device_ids, p_s, p_q)
        return [self.channel_for(t, device_id=int(k)) for k in device_ids]

    def on_arrivals(self, engine, arrivals) -> List[bool]:
        """Batched arrival hook: ``arrivals`` is ``[(now, k, payload, h),
        ...]`` in event order; returns the per-arrival done-round flags.
        Default: the serial ``on_arrival`` in order."""
        return [self.on_arrival(engine, now, k, payload, h)
                for now, k, payload, h in arrivals]

    def aggregate(self, engine, updates: List[Any],
                  weights: List[int]) -> Any:
        raise NotImplementedError(
            f"{self.method} does not run the synchronous loop")

    # -- checkpoint/resume ----------------------------------------------
    # Registered strategies are stateless beyond their bound codec policy
    # (whose per-device staleness EWMAs both engines feed), so the engine
    # checkpoints a strategy by delegating here; a bespoke stateful
    # protocol overrides both hooks.

    def state_dict(self) -> Dict[str, Any]:
        return {"policy": self.policy.state_dict()}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.policy.load_state(state["policy"])


# -- TEA-Fed family: cached staleness-weighted aggregation (Alg. 2) -------
class TeaStrategy(ProtocolStrategy):
    """TEA-Fed: asynchronous cached aggregation, no wire compression."""

    method = "tea"
    arrival_wave = True   # cache semantics fuse exactly (Alg. 2 is order-
    # insensitive within a cache fill); see TeasqServer.receive_many

    def on_arrival(self, engine, now, k, payload, h) -> bool:
        w_local, n_k = engine.resolve_payload(payload)
        return engine.server.receive(w_local, h, n_k)

    def on_arrivals(self, engine, arrivals) -> List[bool]:
        """Fused Alg. 2 over an arrival group: resolve every payload, then
        one ``receive_many`` pass with the stacked Eqs. 6-10 kernel per
        cache fill.  Singletons and serial-mode runs keep the scalar hook
        (``receive``'s sequential-sum aggregation — the pinned path)."""
        if len(arrivals) <= 1 or engine.cfg.handler_mode != "wave":
            return super().on_arrivals(engine, arrivals)
        entries = []
        for _now, _k, payload, h in arrivals:
            w_local, n_k = engine.resolve_payload(payload)
            entries.append((w_local, h, n_k))
        return engine.server.receive_many(entries)


class TeasStrategy(TeaStrategy):
    method = "teas"

    def compression_at(self, t):
        return self.cfg.p_s, 32


class TeaqStrategy(TeaStrategy):
    method = "teaq"

    def compression_at(self, t):
        return 1.0, self.cfg.p_q


class TeaStaticStrategy(TeaStrategy):
    method = "teastatic"

    def compression_at(self, t):
        return self.cfg.p_s, self.cfg.p_q


class TeasqStrategy(TeaStaticStrategy):
    """Full TEASQ-Fed: Alg. 5 decay schedule when provided, else static."""

    method = "teasq"

    def compression_at(self, t):
        if self.cfg.schedule is not None:
            return self.cfg.schedule.at_round(t)
        return self.cfg.p_s, self.cfg.p_q


# -- immediate-update async baselines -------------------------------------
class FedAsyncStrategy(ProtocolStrategy):
    """FedAsync (Xie et al.): mix every arrival straight into the global
    model with a staleness-decayed weight; every arrival is a round."""

    method = "fedasync"

    def mixing_weight(self, staleness: int) -> float:
        cfg = self.cfg
        stale = min(staleness, cfg.max_staleness)   # capped poly decay
        return cfg.alpha * float(staleness_weight(stale, cfg.a))

    def on_arrival(self, engine, now, k, payload, h) -> bool:
        w_local, _ = engine.resolve_payload(payload)
        srv = engine.server
        srv.active = max(0, srv.active - 1)
        a_t = self.mixing_weight(srv.t - h)
        srv.w = jax.tree.map(lambda wl, wg: a_t * wl + (1 - a_t) * wg,
                             w_local, srv.w)
        srv.t += 1
        return True


class PortStrategy(FedAsyncStrategy):
    method = "port"

    def mixing_weight(self, staleness):   # unbounded staleness, harder decay
        return self.cfg.alpha * (staleness + 1.0) ** -1.0


class AsoFedStrategy(FedAsyncStrategy):
    method = "asofed"

    def mixing_weight(self, staleness):   # linear decay
        return self.cfg.alpha / (1.0 + staleness)


# -- synchronous baselines -------------------------------------------------
class FedAvgStrategy(ProtocolStrategy):
    """Synchronous FedAvg: sample a round cohort, wait for the straggler,
    merge by sample-count weights."""

    method = "fedavg"
    event_driven = False

    def aggregate(self, engine, updates, weights):
        wts = np.asarray(weights, np.float32)
        wts /= wts.sum()
        return jax.tree.map(
            lambda *ls: sum(w * l for w, l in zip(wts, ls)), *updates)


class MoonStrategy(FedAvgStrategy):
    """MOON (Li et al., CVPR'21): FedAvg round structure with a model-
    contrastive local objective against the device's previous model."""

    method = "moon"

    def local_train(self, engine, k, w_glob):
        cfg = self.cfg
        task = engine.task
        idx = engine.partitions[k]
        x = engine.data["x_train"][idx]
        y = engine.data["y_train"][idx]
        prev = engine.prev_local.get(k, w_glob)
        params = moon_local_train(w_glob, prev, x, y, epochs=cfg.epochs,
                                  batch_size=cfg.batch_size, lr=cfg.lr,
                                  rng=engine.rng, forward_fn=task.forward,
                                  features_fn=task.features)
        engine.prev_local[k] = params
        return params, len(idx)


STRATEGIES: Dict[str, Type[ProtocolStrategy]] = {
    cls.method: cls for cls in (
        TeaStrategy, TeasStrategy, TeaqStrategy, TeaStaticStrategy,
        TeasqStrategy, FedAsyncStrategy, PortStrategy, AsoFedStrategy,
        FedAvgStrategy, MoonStrategy)
}
assert set(STRATEGIES) == set(METHODS)


def make_strategy(method: str, cfg: SimConfig) -> ProtocolStrategy:
    try:
        return STRATEGIES[method](cfg)
    except KeyError:
        raise ValueError(f"unknown method {method!r}; "
                         f"expected one of {sorted(STRATEGIES)}") from None


# ----------------------------------------------------------------------
# One-call drivers
# ----------------------------------------------------------------------
def make_setup(n_devices: int = 100, iid: bool = True, seed: int = 0,
               n_train: int = 60000, n_test: int = 10000,
               task: str = "fmnist_cnn"):
    """Synthetic (data, partitions, w0) for a registered FLTask — the
    default is the paper's FMNIST CNN workload."""
    t = get_task(task)
    data = t.make_data(n_train, n_test, seed)
    if iid:
        parts = partition_iid(n_train, n_devices, seed)
    else:
        parts = partition_noniid_classes(data["y_train"], n_devices, 2, seed)
    w0 = t.init_params(jax.random.PRNGKey(seed))
    return data, parts, w0


def make_sim(data, parts, w0, cfg: SimConfig, backend: str = "engine"):
    """Build a runnable simulator: the strategy-based engine (default) or
    the legacy monolithic FLSimulator (kept as the parity reference).
    ``cfg.scheduler`` picks the engine's event loop — the reference
    ``"heap"`` or the array-backed ``"batched"`` one (bit-identical
    histories; see ``repro.fl.engine.SCHEDULERS``)."""
    if backend == "legacy":
        return FLSimulator(data, parts, w0, cfg)
    if backend != "engine":
        raise ValueError(f"unknown backend {backend!r}")
    from repro.fl.engine import SCHEDULERS
    try:
        engine_cls = SCHEDULERS[cfg.scheduler]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {cfg.scheduler!r}; "
            f"expected one of {sorted(SCHEDULERS)}") from None
    return engine_cls(data, parts, w0, cfg)


def train_global(data, parts, w0, time_budget: float = 20.0, seed: int = 0,
                 **kw) -> Any:
    """Briefly train a global model (TEA protocol) and return its weights —
    Algorithm 5 profiles compression on a TRAINED model, not the random
    init (a random model's accuracy is insensitive to compression, so the
    search would pick maximum compression)."""
    cfg = SimConfig(method="tea", n_devices=len(parts), seed=seed,
                    **{k: v for k, v in kw.items() if hasattr(SimConfig, k)})
    sim = make_sim(data, parts, w0, cfg)
    sim.run(time_budget=time_budget, eval_every=10 ** 9)
    return sim.server.w


def profile_compression(w: Any, data: Dict[str, np.ndarray], theta: float = 0.02,
                        seed: int = 0, codec: str = "dense",
                        task: str = "fmnist_cnn", tiers=None):
    """Algorithm 5 search on a profiling model ``w``, through the codec
    seam (stochastic QSGD rounding, as the wire applies).  Model-agnostic:
    the accuracy oracle is the task's ``eval_metric``.

    With ``tiers=None`` (the paper's global search) returns
    ``(si, qi, trace)`` — the chosen static point's indices into the
    default candidate sets.  With ``tiers`` — a ``ScenarioConfig.tiers``
    list (or bare bandwidth scales) — runs the per-tier extension
    (:func:`repro.core.dynamic.greedy_search_per_tier`) and returns
    ``(tier_points, traces)`` where ``tier_points[i]`` is tier i's searched
    ``(p_s, p_q)``, directly usable as ``SimConfig.tier_points`` for the
    ``tier_aware`` codec policy."""
    xs = data["x_test"][:2000]
    ys = data["y_test"][:2000]
    eval_jit = jax.jit(get_task(task).eval_metric)
    rng = np.random.RandomState(seed)

    def eval_acc(p_s: float, p_q: int) -> float:
        w2, _ = resolve_codec(codec, p_s, p_q).roundtrip(w, rng=rng)
        return float(eval_jit(w2, xs, ys))

    if tiers is None:
        return greedy_search(eval_acc, theta)
    scales = [getattr(t, "bandwidth_scale", t) for t in tiers]
    points, traces = greedy_search_per_tier(eval_acc, theta, scales)
    return ([(DEFAULT_SET_S[si], DEFAULT_SET_Q[qi]) for si, qi in points],
            traces)


def run_method(method: str, data, parts, w0, *, iid: bool = True,
               time_budget: float = 300.0, seed: int = 0,
               c_fraction: float = 0.1, mu: float = 0.01, alpha: float = 0.6,
               p_s: float = 0.25, p_q: int = 8,
               schedule=None, eval_every: int = 1,
               backend: str = "engine",
               **overrides) -> List[LogEntry]:
    cfg = SimConfig(method=method, n_devices=len(parts),
                    c_fraction=c_fraction, mu=mu, alpha=alpha,
                    p_s=p_s, p_q=p_q, schedule=schedule, seed=seed,
                    **overrides)
    sim = make_sim(data, parts, w0, cfg, backend=backend)
    return sim.run(time_budget=time_budget, eval_every=eval_every)


def best_acc_within(history: List[LogEntry], budget: float) -> float:
    accs = [h.accuracy for h in history if h.time <= budget]
    return max(accs) if accs else float("nan")


def time_to_acc(history: List[LogEntry], target: float) -> Optional[float]:
    for h in history:
        if h.accuracy >= target:
            return h.time
    return None
