"""Protocol runners: one-call drivers for each method in the paper's §5."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.compression import roundtrip_pytree
from repro.core.dynamic import (DEFAULT_SET_Q, DEFAULT_SET_S, greedy_search,
                                make_schedule)
from repro.data.synthetic import (make_fmnist_like, partition_iid,
                                  partition_noniid_classes)
from repro.fl.simulator import FLSimulator, LogEntry, SimConfig
from repro.models.cnn import cnn_accuracy, init_cnn

METHODS = ("fedavg", "fedasync", "tea", "teas", "teaq", "teastatic",
           "teasq", "moon", "port", "asofed")


def make_setup(n_devices: int = 100, iid: bool = True, seed: int = 0,
               n_train: int = 60000, n_test: int = 10000):
    data = make_fmnist_like(n_train, n_test, seed=seed)
    if iid:
        parts = partition_iid(n_train, n_devices, seed)
    else:
        parts = partition_noniid_classes(data["y_train"], n_devices, 2, seed)
    w0 = init_cnn(jax.random.PRNGKey(seed))
    return data, parts, w0


def train_global(data, parts, w0, time_budget: float = 20.0, seed: int = 0,
                 **kw) -> Any:
    """Briefly train a global model (TEA protocol) and return its weights —
    Algorithm 5 profiles compression on a TRAINED model, not the random
    init (a random model's accuracy is insensitive to compression, so the
    search would pick maximum compression)."""
    cfg = SimConfig(method="tea", n_devices=len(parts), seed=seed,
                    **{k: v for k, v in kw.items() if hasattr(SimConfig, k)})
    sim = FLSimulator(data, parts, w0, cfg)
    sim.run(time_budget=time_budget, eval_every=10 ** 9)
    return sim.server.w


def profile_compression(w: Any, data: Dict[str, np.ndarray], theta: float = 0.02,
                        seed: int = 0):
    """Algorithm 5 search on a profiling model ``w``."""
    xs = data["x_test"][:2000]
    ys = data["y_test"][:2000]
    eval_jit = jax.jit(cnn_accuracy)
    rng = np.random.RandomState(seed)

    def eval_acc(p_s: float, p_q: int) -> float:
        w2, _ = roundtrip_pytree(w, p_s, p_q, rng)
        return float(eval_jit(w2, xs, ys))

    return greedy_search(eval_acc, theta)


def run_method(method: str, data, parts, w0, *, iid: bool = True,
               time_budget: float = 300.0, seed: int = 0,
               c_fraction: float = 0.1, mu: float = 0.01, alpha: float = 0.6,
               p_s: float = 0.25, p_q: int = 8,
               schedule=None, eval_every: int = 1,
               **overrides) -> List[LogEntry]:
    cfg = SimConfig(method=method, n_devices=len(parts),
                    c_fraction=c_fraction, mu=mu, alpha=alpha,
                    p_s=p_s, p_q=p_q, schedule=schedule, seed=seed,
                    **overrides)
    sim = FLSimulator(data, parts, w0, cfg)
    return sim.run(time_budget=time_budget, eval_every=eval_every)


def best_acc_within(history: List[LogEntry], budget: float) -> float:
    accs = [h.accuracy for h in history if h.time <= budget]
    return max(accs) if accs else float("nan")


def time_to_acc(history: List[LogEntry], target: float) -> Optional[float]:
    for h in history:
        if h.accuracy >= target:
            return h.time
    return None
