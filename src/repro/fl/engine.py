"""Layered event-driven FL engine: scheduler, device registry, channel
accounting, pluggable protocol strategies, vectorized cohort execution.

Mapping to the paper (TEASQ-Fed, Algs. 1-2):

* **Alg. 1, server side (Distributor)** — ``FLEngine._handle_request``:
  pops a device task request off the virtual-clock event heap and admission-
  controls it through ``TeasqServer.try_dispatch`` (the C-fraction gate,
  P < ceil(N*C)); rejected requests park in the ``waiting`` queue.
* **Alg. 1, device side (local prox-SGD, Eq. 5)** — the trainer layer:
  ``SerialTrainer`` runs ``repro.core.client.local_update`` per device
  (bit-identical to the legacy ``FLSimulator``); ``CohortTrainer`` defers
  training and executes whole cohorts of concurrently-training devices in a
  single jitted scan over the bound task's vectorized ``cohort_loss``
  (``repro.fl.tasks.FLTask`` — the einsum-formulated CNN for the default
  ``fmnist_cnn`` task), one compiled program per padded cohort bucket.
  Which model family trains is ``SimConfig.task``; the engine never touches
  model internals beyond the task object.
* **Algs. 3-4 (wire compression)** — the codec layer
  (``repro.core.codecs``): every dispatch asks the bound strategy for a
  :class:`~repro.core.codecs.Codec` via ``channel_for(t, device_id=k)``,
  which routes the protocol's global (p_s, p_q) point through the bound
  :class:`~repro.fl.policies.CodecPolicy` (``SimConfig.codec_policy`` —
  ``static`` is device-blind, ``tier_aware``/``staleness_aware`` compress
  per device, with per-tier byte totals in ``ChannelMeter``); the serial path
  runs ``codec.roundtrip`` (the faithful reference codec by default, the
  real bit-packed stream with ``SimConfig.codec="packed"``) while the
  cohort path fuses ``ThresholdGraphCodec.apply_tree`` into its jitted scan
  and meters bytes shape-only with ``codec.wire_bytes`` (the packed
  format's size is value-independent, so arrivals can be scheduled before
  training runs).
* **Alg. 2 (Receiver/Updater, Eqs. 6-10)** — ``FLEngine._handle_arrival``
  delegates to the bound :class:`~repro.fl.protocols.ProtocolStrategy`:
  the TEA/TEASQ family feeds ``TeasqServer.receive`` (cached
  staleness-weighted aggregation); FedAsync/PORT/ASO-Fed mix immediately;
  FedAvg/MOON run the synchronous straggler-bound loop instead.

On top sits the scenario-injection layer (``ScenarioConfig``): per-device
dropout, transient mid-round failure with task re-dispatch to the waiting
queue, and heterogeneous compute/bandwidth tiers.  Scenario randomness comes
from a dedicated RNG stream, so an inactive scenario leaves the event stream
bit-identical to the legacy simulator — which is what the fixed-seed parity
suite (tests/test_engine_parity.py) pins down.

Two interchangeable schedulers drive the Alg. 1-2 event loop
(``SimConfig.scheduler``, registry :data:`SCHEDULERS`):

* ``"heap"`` — :class:`FLEngine`: the reference one-``heappop``-at-a-time
  loop, kept untouched as the parity oracle.
* ``"batched"`` — :class:`BatchedEngine`: per-device next-event state lives
  in resident arrays (:class:`EventTable` on :class:`DeviceRegistry`) and
  the next K events are selected in one fused numpy call, preserving the
  heap's exact ``(time, seq)`` order — bit-identical histories at an
  order-of-magnitude lower per-task dispatch cost on 10^4-10^5-device
  fleets (tests/test_batched_engine.py pins the parity,
  ``python -m benchmarks.engine_scale --scheduler batched`` the scale).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import local_update
from repro.core.codecs import Codec, IdentityCodec, ThresholdGraphCodec
from repro.core.latency import (comm_latency, comm_latency_batch,
                                device_rates, sample_compute_latency,
                                sample_compute_latency_batch)
from repro.core.server import ServerConfig, TeasqServer, make_server
from repro.fl.simulator import (LogEntry, ScenarioConfig, SimConfig,
                                tier_assignment)
from repro.fl.tasks import get_task


# ----------------------------------------------------------------------
# Device registry + channel accounting
# ----------------------------------------------------------------------
class DeviceRegistry:
    """Per-device simulation state: link rates, compute coefficients, tier
    assignment, and liveness.  Draws from the engine RNG in exactly the
    legacy ``FLSimulator.__init__`` order (rates, then a_k)."""

    def __init__(self, cfg: SimConfig, rng: np.random.RandomState):
        n = cfg.n_devices
        self.cfg = cfg
        self.down_rates, self.up_rates = device_rates(n, cfg.wireless, rng)
        self.a_k = rng.uniform(cfg.compute.a_min, cfg.compute.a_max, n)
        self.phi_k = np.full(n, cfg.compute.phi)
        self.alive = np.ones(n, bool)
        self.tier = np.zeros(n, np.int64)
        self.events: Optional[EventTable] = None   # batched scheduler only

    def event_table(self) -> "EventTable":
        """The resident per-device next-event arrays (allocated on first
        use — only the batched scheduler needs them)."""
        if self.events is None:
            self.events = EventTable(len(self.alive))
        return self.events

    def apply_tiers(self, tiers) -> None:
        """Scale latency per tier under the shared contiguous assignment
        (``repro.fl.simulator.tier_assignment`` — the same map the codec
        policies use, so latency and codec choice agree per device)."""
        self.tier = tier_assignment(len(self.alive), tiers)
        for i, t in enumerate(tiers):
            sel = self.tier == i
            self.a_k[sel] *= t.compute_scale
            self.down_rates[sel] *= t.bandwidth_scale
            self.up_rates[sel] *= t.bandwidth_scale

    def round_latency(self, k: int, bits_down: float, bits_up: float,
                      n_batches: int, rng: np.random.RandomState
                      ) -> Tuple[float, float, float]:
        cfg = self.cfg
        dl = comm_latency(bits_down, self.down_rates[k])
        ul = comm_latency(bits_up, self.up_rates[k])
        cp = sample_compute_latency(self.a_k[k], self.phi_k[k],
                                    tau_b=n_batches * cfg.epochs
                                    * 0.002 * cfg.batch_size, rng=rng)
        return dl, cp, ul

    def round_latency_batch(self, ks: np.ndarray, bits_down, bits_up,
                            n_batches: np.ndarray,
                            rng: np.random.RandomState
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``round_latency`` over a whole grant wave: same elementwise
        float64 arithmetic, ONE ``rng.exponential(size=G)`` draw for the
        compute latencies.  Wave callers pass ``ks`` sorted ascending, so
        draw i belongs to the i-th lowest device id of the wave — the
        documented ``handler_mode="wave"`` draw order (heap-pop order is
        what the serial path consumes)."""
        cfg = self.cfg
        dl = comm_latency_batch(bits_down, self.down_rates[ks])
        ul = comm_latency_batch(bits_up, self.up_rates[ks])
        tau_b = (np.asarray(n_batches, np.float64) * cfg.epochs
                 * 0.002 * cfg.batch_size)
        cp = sample_compute_latency_batch(self.a_k[ks], self.phi_k[ks],
                                          tau_b, rng)
        return dl, cp, ul


# Event kinds, shared by both schedulers: the heap path stores the name in
# its event tuples, the batched path stores the id in its resident arrays.
KIND_NAMES = ("request", "arrival", "failure")
KIND_IDS = {name: i for i, name in enumerate(KIND_NAMES)}


class EventTable:
    """Resident next-event state for the batched scheduler, one slot per
    device.  The engine's event loop maintains an invariant the heap never
    exploits: every device has AT MOST ONE outstanding event at any time
    (its pending request, its in-flight arrival, or a scheduled
    failure/retry) and events are never cancelled — a device parked in the
    waiting queue or dead simply has no event.  The device id is therefore
    a perfect slot key, and the entire event queue collapses into aligned
    per-device arrays (``time`` is +inf while a slot is empty).

    ``select_batch`` is the scheduler's fused step: one ``np.partition``
    over the times plus one ``np.lexsort`` picks the next <= ``k_max``
    events in exact ``(time, seq)`` heap order.  Ties at the k-th smallest
    time are all included, so a batch boundary can never split — and hence
    never reorder — a group of same-time events."""

    def __init__(self, n: int):
        self.time = np.full(n, np.inf)
        self.seq = np.zeros(n, np.int64)
        self.kind = np.zeros(n, np.int8)
        self.h = np.zeros(n, np.int64)
        # which FL job an event belongs to: 0 for the single-task engines,
        # the task index (or -1 = assign-on-handling) under a multi-task
        # fleet (repro.fl.fleet) — carried through select_batch gathers
        # exactly like ``h``
        self.task = np.zeros(n, np.int32)
        self.payload: List[Any] = [None] * n

    def put(self, k: int, t: float, seq: int, kind: str, payload: Any,
            h: int, task: int = 0) -> None:
        assert self.time[k] == np.inf, \
            f"device {k} already has a scheduled event"
        self.time[k] = t
        self.seq[k] = seq
        self.kind[k] = KIND_IDS[kind]
        self.h[k] = h
        self.task[k] = task
        self.payload[k] = payload

    def clear(self, k: int) -> None:
        self.time[k] = np.inf
        self.payload[k] = None

    def put_wave(self, ks: np.ndarray, ts: np.ndarray, seqs: np.ndarray,
                 kind: str, payloads, h, task: int = 0) -> None:
        """Vectorized ``put`` for a whole wave of same-kind events — one
        scatter per array instead of G scalar slot writes.  ``h``/``task``
        are scalars (a wave shares its dispatch round and job id)."""
        assert np.all(self.time[ks] == np.inf), \
            "a wave member already has a scheduled event"
        self.time[ks] = ts
        self.seq[ks] = seqs
        self.kind[ks] = KIND_IDS[kind]
        self.h[ks] = h
        self.task[ks] = task
        if payloads is None:
            return
        pl = self.payload
        for k, p in zip(ks.tolist(), payloads):
            pl[k] = p

    def clear_wave(self, ks: np.ndarray) -> None:
        self.time[ks] = np.inf
        pl = self.payload
        for k in ks.tolist():
            pl[k] = None

    def select_batch(self, k_max: int) -> np.ndarray:
        """Device ids of the next <= ``k_max`` scheduled events (plus any
        events tied with the k-th time), in global ``(time, seq)`` order."""
        times = self.time
        finite = times < np.inf
        n_live = int(finite.sum())
        if n_live == 0:
            return np.empty(0, np.int64)
        if n_live > k_max:
            kth = np.partition(times, k_max - 1)[k_max - 1]
            cand = np.flatnonzero(times <= kth)
        else:
            cand = np.flatnonzero(finite)
        return cand[np.lexsort((self.seq[cand], times[cand]))]


class _FifoWaiting:
    """FIFO waiting queue with O(1) pops — call-compatible with the heap
    path's plain ``waiting`` list (``append`` / ``pop(0)`` / ``len``), but
    ``pop(0)`` advances a head cursor instead of shifting the buffer, which
    matters when 90% of a 10^5-device fleet parks behind the admission gate
    after the initial request burst."""

    __slots__ = ("_items", "_head")

    def __init__(self):
        self._items: List[int] = []
        self._head = 0

    def __len__(self) -> int:
        return len(self._items) - self._head

    def append(self, k: int) -> None:
        self._items.append(k)

    def pop(self, i: int = 0) -> int:
        assert i == 0, "the waiting queue is FIFO-only"
        k = self._items[self._head]
        self._head += 1
        self._maybe_compact()
        return k

    def extend(self, ks) -> None:
        """Park a whole wave behind the admission gate in one call."""
        self._items.extend(ks)

    def pop_many(self, g: int) -> List[int]:
        """Pop up to ``g`` waiters as ONE slice — the wave-grant drain.
        G scalar ``pop(0)`` calls advance the head cursor G times and can
        trigger G compaction checks; this is a single slice + one check."""
        h = self._head
        out = self._items[h:h + g]
        self._head = h + len(out)
        self._maybe_compact()
        return out

    def _maybe_compact(self) -> None:
        if self._head > 1024 and self._head * 2 >= len(self._items):
            del self._items[:self._head]
            self._head = 0


class ChannelMeter:
    """Cumulative and per-transfer-max byte accounting for both directions.

    Transfers are priced by the wire codec (``codec.wire_bytes`` — shape-only
    and value-independent for every registered codec) via the ``*_tree``
    helpers; the scalar ``down``/``up`` record an already-priced transfer
    (e.g. the serial path, which meters the actual encoded size).  When the
    caller knows the target device's heterogeneity tier it passes ``tier=``
    and the meter additionally keeps per-tier totals (``tier_up`` /
    ``tier_down``) — the accounting behind the tier-aware codec-policy
    acceptance numbers in results/engine_scale.json."""

    def __init__(self):
        self.bytes_up = 0
        self.bytes_down = 0
        self.max_up = 0
        self.max_down = 0
        self.tier_up: Dict[int, int] = {}
        self.tier_down: Dict[int, int] = {}

    def down(self, nbytes: int, tier: Optional[int] = None) -> None:
        self.bytes_down += nbytes
        self.max_down = max(self.max_down, nbytes)
        if tier is not None:
            self.tier_down[tier] = self.tier_down.get(tier, 0) + nbytes

    def up(self, nbytes: int, tier: Optional[int] = None) -> None:
        self.bytes_up += nbytes
        self.max_up = max(self.max_up, nbytes)
        if tier is not None:
            self.tier_up[tier] = self.tier_up.get(tier, 0) + nbytes

    def down_tree(self, codec: Codec, tree: Any,
                  tier: Optional[int] = None) -> int:
        nbytes = codec.wire_bytes(tree)
        self.down(nbytes, tier)
        return nbytes

    def up_tree(self, codec: Codec, tree: Any,
                tier: Optional[int] = None) -> int:
        nbytes = codec.wire_bytes(tree)
        self.up(nbytes, tier)
        return nbytes

    # -- wave accounting: one call per grant wave instead of G scalar
    # calls.  Integer-exact: the bincount accumulates int64 byte counts as
    # float64 (exact below 2^53, far above any simulated transfer volume)
    # and converts back per tier, so per-tier totals match G serial calls.
    def _wave(self, nbytes: np.ndarray, tiers: np.ndarray,
              tier_tot: Dict[int, int]) -> Tuple[int, int]:
        sums = np.bincount(tiers, weights=nbytes)
        for t in np.flatnonzero(sums).tolist():
            tier_tot[t] = tier_tot.get(t, 0) + int(sums[t])
        return int(nbytes.sum()), int(nbytes.max())

    def down_wave(self, nbytes: np.ndarray, tiers: np.ndarray) -> None:
        if not len(nbytes):
            return
        tot, mx = self._wave(nbytes, tiers, self.tier_down)
        self.bytes_down += tot
        self.max_down = max(self.max_down, mx)

    def up_wave(self, nbytes: np.ndarray, tiers: np.ndarray) -> None:
        if not len(nbytes):
            return
        tot, mx = self._wave(nbytes, tiers, self.tier_up)
        self.bytes_up += tot
        self.max_up = max(self.max_up, mx)


@dataclasses.dataclass
class EngineStats:
    dispatches: int = 0
    completions: int = 0
    dropouts: int = 0
    transient_failures: int = 0
    redispatched: int = 0
    flushes: int = 0
    flushed_tasks: int = 0
    completed_per_device: Optional[np.ndarray] = None


# ----------------------------------------------------------------------
# Trainers: serial (legacy-parity) and vectorized cohort
# ----------------------------------------------------------------------
class SerialTrainer:
    """Trains one device at grant time — the rng-order-exact legacy path."""

    deferred = False

    def __init__(self, engine: "FLEngine"):
        self.engine = engine

    def train(self, k: int, w: Any) -> Tuple[Any, int]:
        eng = self.engine
        idx = eng.partitions[k]
        x, y = eng.data["x_train"][idx], eng.data["y_train"][idx]
        w_new, _, _ = local_update(
            w, x, y, eng.task.loss, epochs=eng.cfg.epochs,
            batch_size=eng.cfg.batch_size, lr=eng.cfg.lr, mu=eng.cfg.mu,
            rng=eng.rng)
        return w_new, len(idx)


@dataclasses.dataclass
class PendingTask:
    """A granted-but-not-yet-trained task in the deferred cohort buffer."""
    k: int
    version: int          # index into the flush's global-model version list
    t0: int
    p_s: float
    p_q: int
    n_k: int
    bidx: np.ndarray      # (T, bs) minibatch sample indices
    result: Optional[Tuple[Any, int]] = None


@functools.partial(jax.jit,
                   static_argnames=("cohort_loss", "lr", "mu", "p_s", "p_q",
                                    "iters"))
def _cohort_round(w_versions, vidx, xs, ys, didx, bidx, valid, *,
                  cohort_loss, lr: float, mu: float, p_s: float, p_q: int,
                  iters: int):
    """One fused cohort round: down-channel (per model version), E epochs of
    prox-SGD for every device in the cohort (scan over steps, the task's
    vectorized ``cohort_loss``), up-channel.  Shapes: w_versions leaves
    (V, ...); vidx/didx (C,); xs/ys (N, n_max, ...); bidx (T, C, bs);
    valid (T, C).  ``cohort_loss`` is static (a stable FLTask attribute, so
    each task compiles once per bucket shape)."""

    channel = ThresholdGraphCodec(p_s, p_q, iters).apply_tree

    w_recv_v = jax.vmap(channel)(w_versions)
    w_recv = jax.tree.map(lambda a: a[vidx], w_recv_v)
    xd = xs[didx]
    yd = ys[didx]

    def step(params, sv):
        idx, v = sv                                   # (C, bs), (C,)
        # broadcast the (C, bs) gather over the sample feature axes, whatever
        # their rank (images (C, n, 28, 28, 1), token matrices (C, n, S), ...)
        inputs = jnp.take_along_axis(
            xd, idx.reshape(idx.shape + (1,) * (xd.ndim - 2)), axis=1)
        labs = jnp.take_along_axis(yd, idx, axis=1)
        grads = jax.grad(cohort_loss)(params, inputs, labs)

        def upd(p, g, a):
            vv = v.reshape((v.shape[0],) + (1,) * (p.ndim - 1))
            return p - vv * lr * (g + mu * (p - a))

        return jax.tree.map(upd, params, grads, w_recv), None

    out, _ = jax.lax.scan(step, w_recv, (bidx, valid))
    return jax.vmap(channel)(out)


@functools.partial(jax.jit, static_argnames=("p_s", "p_q", "iters"))
def _zero_step_round(w_versions, *, p_s: float, p_q: int, iters: int):
    """Wave-mode cohort fast path for groups with ZERO local steps (every
    member has n_k < batch_size, the dispatch-benchmark regime): with no
    SGD steps the up-channel input is exactly the down-channel output, so
    the cohort result depends only on the model VERSION — encode the V
    distinct versions twice (down then up) instead of running the C-wide
    ``_cohort_round`` (V ~= C / cache_size under the admission gate, a
    ~K-fold cut in channel work).  Per-task results are gathers of the
    (V, ...) output on the host side."""
    channel = ThresholdGraphCodec(p_s, p_q, iters).apply_tree
    return jax.vmap(lambda w: channel(channel(w)))(w_versions)


class CohortTrainer:
    """Deferred vectorized execution: granted tasks buffer up and whole
    cohorts train in one jitted call (padded to power-of-two buckets so jit's
    shape cache stays small).  Device data is pre-stacked once; minibatch
    permutations come from a dedicated RNG (the deferred path makes no
    bit-parity promise, only distributional equivalence)."""

    deferred = True

    def __init__(self, engine: "FLEngine", cohort_size: int,
                 channel_iters: int = 12):
        self.engine = engine
        self.cohort_size = max(1, cohort_size)
        self.channel_iters = channel_iters
        self.perm_rng = np.random.RandomState(engine.cfg.seed + 0x9E3779)
        self._serial = SerialTrainer(engine)   # sync-loop fallback
        self.pending: List[PendingTask] = []
        self._versions: List[Any] = []
        self._version_ids: Dict[int, int] = {}
        parts = engine.partitions
        n_max = max(len(idx) for idx in parts)
        x = engine.data["x_train"]
        xs = np.zeros((len(parts), n_max) + x.shape[1:], x.dtype)
        ys = np.zeros((len(parts), n_max), np.int32)
        for k, idx in enumerate(parts):
            xs[k, :len(idx)] = x[idx]
            ys[k, :len(idx)] = engine.data["y_train"][idx]
        self.xs = jnp.asarray(xs)
        self.ys = jnp.asarray(ys)
        # two padded-shape buckets: full cohorts and a small one for tail
        # flushes — each bucket costs one XLA compile of _cohort_round
        self.buckets = sorted({max(1, self.cohort_size // 4),
                               self.cohort_size})

    # -- sync-loop fallback -------------------------------------------------
    def train(self, k: int, w: Any) -> Tuple[Any, int]:
        return self._serial.train(k, w)

    # -- deferred protocol --------------------------------------------------
    def _version_of(self, w: Any) -> int:
        vid = self._version_ids.get(id(w))
        if vid is None:
            vid = len(self._versions)
            self._versions.append(w)       # keeps the ref alive => id stable
            self._version_ids[id(w)] = vid
        return vid

    def submit(self, k: int, w_t: Any, t0: int, p_s: float,
               p_q: int) -> PendingTask:
        cfg = self.engine.cfg
        n_k = len(self.engine.partitions[k])
        bs = cfg.batch_size
        steps = (n_k - bs) // bs + 1 if n_k >= bs else 0
        rows = []
        for _ in range(cfg.epochs):
            order = self.perm_rng.permutation(n_k)
            for s in range(steps):
                rows.append(order[s * bs:(s + 1) * bs])
        bidx = (np.asarray(rows, np.int32) if rows
                else np.zeros((0, bs), np.int32))
        task = PendingTask(k, self._version_of(w_t), t0, p_s, p_q, n_k, bidx)
        self.pending.append(task)
        if len(self.pending) >= self.cohort_size:
            self.flush()
        return task

    def result(self, task: PendingTask) -> Tuple[Any, int]:
        if task.result is None:
            self.flush()
        assert task.result is not None
        return task.result

    @staticmethod
    def _pad_pow2(n: int) -> int:
        p = 1
        while p < n:
            p *= 2
        return p

    def flush(self) -> None:
        tasks, self.pending = self.pending, []
        versions, self._versions = self._versions, []
        self._version_ids = {}
        if not tasks:
            return
        groups: Dict[Tuple[float, int], List[PendingTask]] = {}
        for t in tasks:
            groups.setdefault((t.p_s, t.p_q), []).append(t)
        # pad the version axis to a power of two (repeat the first version)
        # so the jitted program's V dimension comes from a small bucket set
        versions = versions + [versions[0]] * (self._pad_pow2(len(versions))
                                               - len(versions))
        w_versions = jax.tree.map(lambda *ls: jnp.stack(ls), *versions)
        for (p_s, p_q), group in groups.items():
            self._flush_group(group, w_versions, p_s, p_q)
        self.engine.stats.flushes += 1
        self.engine.stats.flushed_tasks += len(tasks)

    def _flush_group(self, group: List[PendingTask], w_versions, p_s: float,
                     p_q: int) -> None:
        cfg = self.engine.cfg
        c = len(group)
        c_pad = next(b for b in self.buckets if b >= c) if \
            c <= self.buckets[-1] else c
        # pad the scan length to a power of two too (ragged partitions give
        # per-device step counts; valid=0 masks the padding) — otherwise
        # every distinct t_max recompiles the fused round
        t_max = max(t.bidx.shape[0] for t in group)
        t_max = self._pad_pow2(t_max) if t_max else 0
        if t_max == 0 and cfg.handler_mode == "wave":
            # zero local steps => the result is a pure function of the
            # version; gated to wave mode so the serial path keeps running
            # the exact pinned _cohort_round program
            w_up_v = _zero_step_round(w_versions, p_s=p_s, p_q=p_q,
                                      iters=self.channel_iters)
            w_np = jax.tree.map(np.asarray, w_up_v)
            for t in group:
                t.result = (jax.tree.map(lambda a, v=t.version: a[v], w_np),
                            t.n_k)
            return
        bs = cfg.batch_size
        bidx = np.zeros((c_pad, t_max, bs), np.int32)
        valid = np.zeros((c_pad, t_max), np.float32)
        vidx = np.zeros(c_pad, np.int32)
        didx = np.zeros(c_pad, np.int32)
        for i, t in enumerate(group):
            ti = t.bidx.shape[0]
            bidx[i, :ti] = t.bidx
            valid[i, :ti] = 1.0
            vidx[i] = t.version
            didx[i] = t.k
        w_up = _cohort_round(
            w_versions, jnp.asarray(vidx), self.xs, self.ys,
            jnp.asarray(didx), jnp.asarray(np.swapaxes(bidx, 0, 1)),
            jnp.asarray(np.swapaxes(valid, 0, 1)),
            cohort_loss=self.engine.task.cohort_loss,
            lr=cfg.lr, mu=cfg.mu, p_s=p_s, p_q=p_q,
            iters=self.channel_iters)
        # one bulk device->host transfer per leaf; per-task results are then
        # free numpy views (a per-task jnp slice costs an eager dispatch,
        # which dominated the flush at large N)
        w_up_np = jax.tree.map(np.asarray, w_up)
        for i, t in enumerate(group):
            t.result = (jax.tree.map(lambda a, i=i: a[i], w_up_np), t.n_k)


# ----------------------------------------------------------------------
# Checkpoint helpers (engine + fleet state_dict/load_state)
# ----------------------------------------------------------------------
def _pack_rng(rng: np.random.RandomState) -> List[Any]:
    name, keys, pos, has_gauss, cached = rng.get_state()
    return [name, np.asarray(keys), int(pos), int(has_gauss), float(cached)]


def _load_rng(rng: np.random.RandomState, packed) -> None:
    rng.set_state((packed[0], np.asarray(packed[1], np.uint32),
                   int(packed[2]), int(packed[3]), float(packed[4])))


def _trees_equal(a: Any, b: Any) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ----------------------------------------------------------------------
# The engine
# ----------------------------------------------------------------------
class FLEngine:
    """Event-driven virtual-clock FL engine with pluggable protocol
    strategies.  Drop-in for the legacy ``FLSimulator``: with default knobs
    (no scenario, ``cohort_size=0``) it consumes the seeded RNG in the exact
    legacy order and reproduces its ``LogEntry`` history bit-for-bit."""

    supports_wave = False   # handler_mode="wave" needs the batched arrays

    def __init__(self, data: Dict[str, np.ndarray],
                 partitions: List[np.ndarray], w_init: Any, cfg: SimConfig,
                 strategy: Optional[Any] = None, *,
                 rng: Optional[np.random.RandomState] = None,
                 devices: Optional[DeviceRegistry] = None,
                 scenario_rng: Optional[np.random.RandomState] = None):
        """``rng`` / ``devices`` / ``scenario_rng`` let a multi-task fleet
        (``repro.fl.fleet.MultiTaskEngine``) share one seeded RNG stream and
        one :class:`DeviceRegistry` across several per-task engines; when a
        registry is injected the fleet owns tier application and the event
        loop, and this engine acts as a per-task runtime (its handlers are
        driven by the fleet's scheduler).  Standalone construction (the
        default) is unchanged and draws the RNG in the legacy order."""
        self.cfg = cfg
        self.data = data
        self.partitions = partitions
        self.shared_fleet = devices is not None
        self.rng = np.random.RandomState(cfg.seed) if rng is None else rng
        n = cfg.n_devices
        assert len(partitions) == n
        if cfg.handler_mode not in ("serial", "wave"):
            raise ValueError(
                f"unknown handler_mode {cfg.handler_mode!r}; "
                "expected 'serial' or 'wave'")
        if cfg.handler_mode == "wave" and not self.supports_wave:
            raise ValueError(
                "handler_mode='wave' needs the batched scheduler "
                "(SimConfig.scheduler='batched')")
        # per-device partition sizes, resident for vectorized n_batches
        self.part_sizes = np.asarray([len(p) for p in partitions], np.int64)
        self.devices = (DeviceRegistry(cfg, self.rng) if devices is None
                        else devices)
        self.server = make_server(cfg.server, w_init, ServerConfig(
            n, cfg.c_fraction, cfg.gamma, cfg.alpha, cfg.a),
            shards=cfg.server_shards)
        self.channel = ChannelMeter()
        self.prev_local: Dict[int, Any] = {}      # MOON per-device state
        self.task = get_task(cfg.task)
        self._eval = jax.jit(self.task.eval_metric)
        self.history: List[LogEntry] = []
        self.stats = EngineStats(completed_per_device=np.zeros(n, np.int64))
        self._treedef = jax.tree_util.tree_structure(w_init)

        if strategy is None:
            from repro.fl.protocols import make_strategy
            strategy = make_strategy(cfg.method, cfg)
        self.strategy = strategy

        self.scenario: Optional[ScenarioConfig] = cfg.scenario
        self.scenario_rng = (np.random.RandomState(
            (cfg.seed + 0x5CE7A710) % (2 ** 31))
            if scenario_rng is None else scenario_rng)
        if (not self.shared_fleet and self.scenario is not None
                and self.scenario.tiers):
            self.devices.apply_tiers(self.scenario.tiers)

        self.trainer = (CohortTrainer(self, cfg.cohort_size,
                                      cfg.cohort_channel_iters)
                        if cfg.cohort_size > 0 else SerialTrainer(self))

        # resumable-loop state (checkpoint/resume lives here: ``run`` picks
        # up exactly where a previous call stopped, and ``state_dict`` /
        # ``load_state`` serialize it — see the checkpoint section below)
        self._started = False
        self._now = 0.0
        self._seq = 0
        self._events: Optional[List[Tuple]] = None     # heap scheduler
        self._waiting: Optional[Any] = None
        self._tail_logged = False
        self._sync_now = 0.0

    # -- shared helpers ----------------------------------------------------
    def resolve_payload(self, payload: Any) -> Tuple[Any, int]:
        """(w_local, n_k) from either an eager tuple or a PendingTask."""
        if isinstance(payload, PendingTask):
            return self.trainer.result(payload)
        return payload

    def evaluate(self) -> float:
        xs, ys = self.data["x_test"], self.data["y_test"]
        accs = []
        for s in range(0, len(ys), 2000):
            accs.append(float(self._eval(self.server.w,
                                         jnp.asarray(xs[s:s + 2000]),
                                         jnp.asarray(ys[s:s + 2000]))))
        return float(np.mean(accs))

    def _log(self, time: float) -> None:
        self.history.append(LogEntry(
            time, self.server.t, self.evaluate(), self.channel.bytes_up,
            self.channel.bytes_down, self.channel.max_up,
            self.channel.max_down))

    # -- entry point -------------------------------------------------------
    def run(self, time_budget: float = 300.0, max_rounds: int = 10 ** 9,
            eval_every: int = 1) -> List[LogEntry]:
        if not self.strategy.event_driven:
            return self._run_sync(time_budget, max_rounds, eval_every)
        return self._run_async(time_budget, max_rounds, eval_every)

    # -- asynchronous event loop (Algs. 1-2) -------------------------------
    def _resume(self) -> None:
        """Drop the previous ``run`` call's trailing budget log so that
        ``run(t)`` + ``run(T)`` produces exactly ``run(T)``'s history — the
        invariant the checkpoint/resume bit-parity tests pin."""
        if self._tail_logged:
            self.history.pop()
            self._tail_logged = False

    def _push(self, t, kind, k, payload=None, h=0):
        heapq.heappush(self._events, (t, self._seq, kind, k, payload, h))
        self._seq += 1

    def _run_async(self, time_budget: float, max_rounds: int,
                   eval_every: int) -> List[LogEntry]:
        cfg = self.cfg
        self._resume()
        if not self._started:
            self._events = []
            self._waiting = []
            for k in range(cfg.n_devices):
                self._push(self.rng.uniform(0, 0.05), "request", k)
            self._log(0.0)
            self._started = True

        events, waiting, push = self._events, self._waiting, self._push
        now = self._now
        while events:
            # peek: a stop leaves the boundary event queued, so a later
            # ``run`` call (or a restored checkpoint) resumes exactly here;
            # ``now`` still advances to the boundary time, which is what the
            # pre-resume loop logged (it popped the event it then dropped)
            t_next = events[0][0]
            if t_next > time_budget or self.server.t >= max_rounds:
                now = t_next
                break
            now, _, kind, k, payload, h = heapq.heappop(events)
            if kind == "request":
                self._handle_request(now, k, push, waiting)
            elif kind == "failure":
                self._handle_failure(now, k, payload, push, waiting)
            else:
                self._handle_arrival(now, k, payload, h, eval_every, push,
                                     waiting)
        self._now = now
        self._log(min(now, time_budget))
        self._tail_logged = True
        return self.history

    def _drain_waiting(self, now, push, waiting) -> None:
        # re-issue at most free-slot many waiting requests: re-pushing the
        # whole queue is FIFO-equivalent (ungranted requests re-queue in
        # order) but costs O(waiting) events per freed slot — quadratic at
        # large N
        free = self.server.cfg.max_parallel - self.server.active
        for _ in range(min(free, len(waiting))):
            push(now, "request", waiting.pop(0))

    def _handle_request(self, now, k, push, waiting) -> None:
        cfg = self.cfg
        if not self.devices.alive[k]:
            return
        grant = self.server.try_dispatch()
        if grant is None:
            waiting.append(k)
            return
        self.stats.dispatches += 1
        w_t, t0 = grant
        codec = self.strategy.channel_for(t0, device_id=k)
        tier = int(self.devices.tier[k])

        if self.scenario is not None and self.scenario.active:
            scen = self.scenario
            u = self.scenario_rng.random_sample()
            if u < scen.dropout_prob + scen.failure_prob:
                mode = "dropout" if u < scen.dropout_prob else "transient"
                nbytes_down = self.channel.down_tree(codec, w_t, tier)
                n_k = len(self.partitions[k])
                n_batches = max(1, n_k // cfg.batch_size)
                dl, cp, _ = self.devices.round_latency(
                    k, nbytes_down * 8, 0.0, n_batches, self.scenario_rng)
                fail_at = now + self.scenario_rng.uniform(0.0, dl + cp)
                push(fail_at, "failure", k, mode)
                return

        if self.trainer.deferred:
            nbytes_down = self.channel.down_tree(codec, w_t, tier)
            task = self.trainer.submit(k, w_t, t0, codec.p_s, codec.p_q)
            # same tree shapes and (p_s, p_q) => nbytes_up == nbytes_down
            nbytes_up = self.channel.up_tree(codec, w_t, tier)
            n_batches = max(1, task.n_k // cfg.batch_size)
            dl, cp, ul = self.devices.round_latency(
                k, nbytes_down * 8, nbytes_up * 8, n_batches, self.rng)
            push(now + dl + cp + ul, "arrival", k, task, t0)
            return

        w_recv, nbytes_down = codec.roundtrip(w_t, rng=self.rng)
        self.channel.down(nbytes_down, tier)
        w_local, n_k = self.strategy.local_train(self, k, w_recv)
        w_up, nbytes_up = codec.roundtrip(w_local, rng=self.rng)
        self.channel.up(nbytes_up, tier)
        n_batches = max(1, n_k // cfg.batch_size)
        dl, cp, ul = self.devices.round_latency(
            k, nbytes_down * 8, nbytes_up * 8, n_batches, self.rng)
        push(now + dl + cp + ul, "arrival", k, (w_up, n_k), t0)

    def _handle_failure(self, now, k, mode, push, waiting) -> None:
        """Mid-round device loss: free the slot, re-dispatch the capacity to
        the waiting queue; transient failures retry after a backoff."""
        self.server.active = max(0, self.server.active - 1)
        if mode == "dropout":
            self.devices.alive[k] = False
            self.stats.dropouts += 1
        else:
            self.stats.transient_failures += 1
            push(now + self.scenario.retry_backoff, "request", k)
        if waiting:
            self.stats.redispatched += 1
        self._drain_waiting(now, push, waiting)

    def _handle_arrival(self, now, k, payload, h, eval_every, push,
                        waiting) -> None:
        # feed the codec policy's per-device staleness estimator (no-op for
        # the static policy; draws no RNG, so parity runs are untouched)
        self.strategy.policy.observe_arrival(k, max(0, self.server.t - h))
        done_round = self.strategy.on_arrival(self, now, k, payload, h)
        self.stats.completions += 1
        self.stats.completed_per_device[k] += 1
        if done_round and self.server.t % eval_every == 0:
            self._log(now)
        if self.devices.alive[k]:
            push(now, "request", k)
        self._drain_waiting(now, push, waiting)

    # -- synchronous loop (FedAvg / MOON) ----------------------------------
    def _run_sync(self, time_budget: float, max_rounds: int,
                  eval_every: int) -> List[LogEntry]:
        cfg = self.cfg
        now = self._sync_now
        if not self._started:
            self._log(now)
            self._started = True
        per_round = min(cfg.devices_per_round, cfg.n_devices)
        identity = IdentityCodec()       # FedAvg/MOON ship dense f32
        while now < time_budget and self.server.t < max_rounds:
            sel = self.rng.choice(cfg.n_devices, per_round, replace=False)
            updates, weights, latencies = [], [], []
            for k in sel:
                tier = int(self.devices.tier[k])
                nbytes = self.channel.down_tree(identity, self.server.w,
                                                tier)
                w_local, n_k = self.strategy.local_train(self, k,
                                                         self.server.w)
                self.channel.up(nbytes, tier)
                n_batches = max(1, n_k // cfg.batch_size)
                dl, cp, ul = self.devices.round_latency(
                    k, nbytes * 8, nbytes * 8, n_batches, self.rng)
                latencies.append(dl + cp + ul)
                updates.append(w_local)
                weights.append(n_k)
            self.server.w = self.strategy.aggregate(self, updates, weights)
            self.server.t += 1
            now += max(latencies)        # straggler-bound synchronous round
            if self.server.t % eval_every == 0:
                self._log(now)
        self._sync_now = now
        return self.history

    # -- checkpoint/resume -------------------------------------------------
    # Full-sim-state serialization.  Everything below produces / consumes a
    # plain nested structure of dicts, lists, scalars and numpy arrays —
    # exactly what ``repro.checkpoint.io.save_blob`` msgpacks.  Model
    # pytrees are stored as flat leaf lists and rebuilt against the engine's
    # own treedef (captured from ``w_init`` at construction), so a restored
    # engine must be built with the same (data, partitions, w_init, cfg).
    # ``PendingTask`` objects can be referenced both from the deferred
    # cohort buffer and from in-flight arrival events; a shared registry
    # (``reg = (id->index, list)``) preserves that object identity across
    # the roundtrip, which is what keeps resumed runs bit-identical.

    def _pack_tree(self, tree: Any) -> List[np.ndarray]:
        return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]

    def _unpack_tree(self, leaves) -> Any:
        return jax.tree_util.tree_unflatten(
            self._treedef, [np.asarray(l) for l in leaves])

    def _pack_payload(self, payload: Any, reg) -> List[Any]:
        idx, pts = reg
        if payload is None:
            return ["none"]
        if isinstance(payload, str):         # failure mode tag
            return ["str", payload]
        if isinstance(payload, PendingTask):
            i = idx.get(id(payload))
            if i is None:
                i = len(pts)
                idx[id(payload)] = i
                pts.append(payload)
            return ["pending", i]
        w_up, n_k = payload                  # eager (w_local, n_k) tuple
        return ["tree", self._pack_tree(w_up), int(n_k)]

    def _unpack_payload(self, packed, pts: List[PendingTask]) -> Any:
        tag = packed[0]
        if tag == "none":
            return None
        if tag == "str":
            return packed[1]
        if tag == "pending":
            return pts[int(packed[1])]
        return self._unpack_tree(packed[1]), int(packed[2])

    def _pack_pending(self, reg) -> List[Any]:
        return [[int(p.k), int(p.version), int(p.t0), float(p.p_s),
                 int(p.p_q), int(p.n_k), np.asarray(p.bidx),
                 None if p.result is None
                 else [self._pack_tree(p.result[0]), int(p.result[1])]]
                for p in reg[1]]

    def _unpack_pending(self, packed) -> List[PendingTask]:
        pts = []
        for k, version, t0, p_s, p_q, n_k, bidx, result in packed:
            p = PendingTask(int(k), int(version), int(t0), float(p_s),
                            int(p_q), int(n_k), np.asarray(bidx, np.int32))
            if result is not None:
                p.result = (self._unpack_tree(result[0]), int(result[1]))
            pts.append(p)
        return pts

    def _core_state(self, reg) -> Dict[str, Any]:
        """Per-task state: everything except the shared fleet pieces (RNG
        streams, DeviceRegistry, event queue) — a fleet saves those once."""
        srv, ch, st = self.server, self.channel, self.stats
        core = {
            "server": {"w": self._pack_tree(srv.w), "t": int(srv.t),
                       "active": int(srv.active),
                       "cache": [[self._pack_tree(w), int(h), int(n)]
                                 for w, h, n in srv.cache]},
            "strategy": self.strategy.state_dict(),
            "prev_local": [[int(k), self._pack_tree(w)]
                           for k, w in self.prev_local.items()],
            "channel": {"bytes_up": int(ch.bytes_up),
                        "bytes_down": int(ch.bytes_down),
                        "max_up": int(ch.max_up),
                        "max_down": int(ch.max_down),
                        "tier_up": [[int(t), int(b)]
                                    for t, b in ch.tier_up.items()],
                        "tier_down": [[int(t), int(b)]
                                      for t, b in ch.tier_down.items()]},
            "history": [[float(e.time), int(e.round), float(e.accuracy),
                         int(e.bytes_up), int(e.bytes_down),
                         int(e.max_model_bytes_up),
                         int(e.max_model_bytes_down)]
                        for e in self.history],
            "stats": {"dispatches": int(st.dispatches),
                      "completions": int(st.completions),
                      "dropouts": int(st.dropouts),
                      "transient_failures": int(st.transient_failures),
                      "redispatched": int(st.redispatched),
                      "flushes": int(st.flushes),
                      "flushed_tasks": int(st.flushed_tasks),
                      "completed_per_device":
                      np.asarray(st.completed_per_device)},
            "tail_logged": bool(self._tail_logged),
            "sync_now": float(self._sync_now),
            "trainer": None,
        }
        tr = self.trainer
        if isinstance(tr, CohortTrainer):
            idx, pts = reg
            refs = []
            for p in tr.pending:
                i = idx.get(id(p))
                if i is None:
                    i = len(pts)
                    idx[id(p)] = i
                    pts.append(p)
                refs.append(i)
            core["trainer"] = {
                "perm_rng": _pack_rng(tr.perm_rng),
                "pending": refs,
                "versions": [self._pack_tree(v) for v in tr._versions],
            }
        return core

    def _load_core(self, core, pts: List[PendingTask]) -> None:
        srv = self.server
        srv.w = self._unpack_tree(core["server"]["w"])
        srv.t = int(core["server"]["t"])
        srv.active = int(core["server"]["active"])
        srv.cache = [(self._unpack_tree(w), int(h), int(n))
                     for w, h, n in core["server"]["cache"]]
        self.strategy.load_state(core["strategy"])
        self.prev_local = {int(k): self._unpack_tree(w)
                           for k, w in core["prev_local"]}
        ch, c = self.channel, core["channel"]
        ch.bytes_up = int(c["bytes_up"])
        ch.bytes_down = int(c["bytes_down"])
        ch.max_up = int(c["max_up"])
        ch.max_down = int(c["max_down"])
        ch.tier_up = {int(t): int(b) for t, b in c["tier_up"]}
        ch.tier_down = {int(t): int(b) for t, b in c["tier_down"]}
        self.history = [LogEntry(float(t), int(r), float(a), int(bu),
                                 int(bd), int(mu), int(md))
                        for t, r, a, bu, bd, mu, md in core["history"]]
        s = core["stats"]
        self.stats = EngineStats(
            int(s["dispatches"]), int(s["completions"]), int(s["dropouts"]),
            int(s["transient_failures"]), int(s["redispatched"]),
            int(s["flushes"]), int(s["flushed_tasks"]),
            completed_per_device=np.asarray(s["completed_per_device"],
                                            np.int64))
        self._tail_logged = bool(core["tail_logged"])
        self._sync_now = float(core["sync_now"])
        if core["trainer"] is not None:
            tr = self.trainer
            assert isinstance(tr, CohortTrainer), \
                "checkpoint holds a deferred cohort buffer but this engine " \
                "was built with cohort_size=0"
            _load_rng(tr.perm_rng, core["trainer"]["perm_rng"])
            tr.pending = [pts[int(i)] for i in core["trainer"]["pending"]]
            tr._versions = [self._unpack_tree(v)
                            for v in core["trainer"]["versions"]]
            tr._version_ids = {id(v): i for i, v in enumerate(tr._versions)}
            # the restored global model is a fresh object; re-intern it if
            # it was one of the buffered versions so post-resume submits
            # reuse the slot an uninterrupted run would
            for i, v in enumerate(tr._versions):
                if _trees_equal(v, srv.w):
                    tr._version_ids[id(srv.w)] = i
                    break

    def _sched_state(self, reg) -> Dict[str, Any]:
        events = None
        if self._events is not None:
            events = [[float(t), int(s), kind, int(k),
                       self._pack_payload(p, reg), int(h)]
                      for t, s, kind, k, p, h in self._events]
        waiting = (None if self._waiting is None
                   else [int(x) for x in list(self._waiting)])
        return {"events": events, "waiting": waiting}

    def _load_sched(self, st, pts: List[PendingTask]) -> None:
        ev = st["events"]
        self._events = None if ev is None else [
            (float(t), int(s), str(kind), int(k),
             self._unpack_payload(p, pts), int(h))
            for t, s, kind, k, p, h in ev]
        w = st["waiting"]
        self._waiting = None if w is None else [int(x) for x in w]

    def state_dict(self) -> Dict[str, Any]:
        """Serializable full simulation state — server cache, codec-policy
        EWMAs, the DeviceRegistry, the event queue / EventTable, every RNG
        stream, history/stats/byte meters, and any deferred cohort buffer.
        Plain dicts/lists/scalars/ndarrays throughout: feed it to
        ``repro.checkpoint.io.save_blob``.  Restore with :meth:`load_state`
        on a freshly constructed engine over the same (data, partitions,
        w_init, cfg); a resumed ``run`` is bit-identical to an
        uninterrupted one (tests/test_fleet.py pins this)."""
        reg = ({}, [])
        dv = self.devices
        state = {
            "version": 1,
            "rng": _pack_rng(self.rng),
            "scenario_rng": _pack_rng(self.scenario_rng),
            "devices": {"down_rates": np.asarray(dv.down_rates),
                        "up_rates": np.asarray(dv.up_rates),
                        "a_k": np.asarray(dv.a_k),
                        "phi_k": np.asarray(dv.phi_k),
                        "alive": np.asarray(dv.alive),
                        "tier": np.asarray(dv.tier)},
            "started": bool(self._started),
            "now": float(self._now),
            "seq": int(self._seq),
            "sched": self._sched_state(reg),
            "core": self._core_state(reg),
        }
        state["pending"] = self._pack_pending(reg)
        return state

    def load_state(self, state: Dict[str, Any]) -> None:
        if int(state["version"]) != 1:
            raise ValueError(
                f"unknown engine checkpoint version {state['version']!r}")
        _load_rng(self.rng, state["rng"])
        _load_rng(self.scenario_rng, state["scenario_rng"])
        dv, d = self.devices, state["devices"]
        dv.down_rates[:] = np.asarray(d["down_rates"])
        dv.up_rates[:] = np.asarray(d["up_rates"])
        dv.a_k[:] = np.asarray(d["a_k"])
        dv.phi_k[:] = np.asarray(d["phi_k"])
        dv.alive[:] = np.asarray(d["alive"], bool)
        dv.tier[:] = np.asarray(d["tier"])
        self._started = bool(state["started"])
        self._now = float(state["now"])
        self._seq = int(state["seq"])
        pts = self._unpack_pending(state["pending"])
        self._load_core(state["core"], pts)
        self._load_sched(state["sched"], pts)


# ----------------------------------------------------------------------
# Batched scheduler (SimConfig.scheduler = "batched")
# ----------------------------------------------------------------------
class BatchedEngine(FLEngine):
    """The same event machine as ``FLEngine``, with the heap replaced by
    the resident per-device arrays of :class:`EventTable` — the scheduler
    the 10^5-device runs in results/engine_scale.json use.

    Mapping back to the paper: nothing protocol-visible changes.  Alg. 1's
    Distributor still admission-controls requests through
    ``TeasqServer.try_dispatch`` and Alg. 2's Receiver/Updater still runs
    per arrival — the batched loop only changes *how the next event is
    found*, not what any event does.  What is batched:

    * **Selection** — instead of one ``heappop`` + ``heappush`` pair per
      event, the next ``SELECT_K`` events are picked in one fused numpy
      call over the ``EventTable`` arrays (``np.partition`` + ``lexsort``),
      reproducing the exact global ``(time, seq)`` order the heap would
      produce.  Events pushed *during* a batch land back in the arrays;
      those falling inside the current batch's horizon also enter a small
      overflow heap that the merged loop interleaves, so handlers observe
      the identical event order — and therefore consume the shared RNG
      streams in the identical order.  Bit-parity holds by construction
      and is pinned by tests/test_batched_engine.py.
    * **The initial request burst** — one vectorized ``uniform`` draw,
      stream-identical to ``n`` scalar draws from the same RandomState.
    * **Arrival hooks** — arrivals route through the strategies' batched
      hooks (``ProtocolStrategy.on_arrivals`` /
      ``CodecPolicy.observe_arrivals``); the default implementations fall
      back to the serial hooks, and the engine keeps groups singleton
      because each arrival's eval log and re-request must interleave
      before the next arrival.  Protocols that can tolerate coarser
      interleaving override the batched hooks to fuse Eqs. 6-10 across a
      group.
    * **The waiting queue** — an O(1)-pop FIFO (the heap path's
      ``list.pop(0)`` shifts the whole buffer, quadratic when most of a
      large fleet parks behind the C-fraction admission gate).

    The request/failure handlers are inherited unchanged; the heap path
    stays untouched as the parity oracle.

    **Wave mode** (``SimConfig.handler_mode="wave"``) replaces the scalar
    fall-through with vectorized *wave* handlers: each selected batch is
    split into maximal same-kind event runs and every run is processed as
    arrays —

    * **grant waves** (Alg. 1 Distributor): one liveness mask, one
      admission-gate slice (the first ``free`` run members dispatch, the
      rest park via a single ``_FifoWaiting.extend``), codecs for the whole
      wave via ``channels_for`` with per-unique-codec wire pricing, and ONE
      ``DeviceRegistry.round_latency_batch`` call whose RNG draws are
      assigned in ascending device-index order; the resulting arrivals
      scatter into the ``EventTable`` in one ``put_wave``.
    * **arrival waves** (Alg. 2 Receiver/Updater, Eqs. 6-10): one
      ``CodecPolicy.observe_arrivals`` scatter, then
      ``ProtocolStrategy.on_arrivals`` — the TEA family fuses the cache
      insert + staleness-weighted aggregation through the *stacked*
      Eqs. 6-10 kernel (``aggregate_cache_stacked``), one segment per
      cache fill so eval logs observe the exact per-round server state.
      Re-requests and the waiting-queue drain (one ``pop_many`` slice)
      follow as a single request scatter.

    The relaxed-parity contract vs. ``"serial"``: protocol decisions still
    happen in global ``(time, seq)`` event order, but (1) RNG draws are
    batched per wave — grant latencies in device-index order, scenario
    draws in wave order — instead of interleaved per heap pop; (2) events
    spawned by a wave member are processed after the wave, never between
    members, so a re-dispatch within a wave observes the post-wave server
    state — in particular an arrival spawned *inside* an arrival wave's
    time span lands after it, which can regroup cache fills and shift
    round-completion instants relative to the heap order (the effect
    shrinks as fleets grow and waves become time-dense); (3) one
    aggregation reduces via tensordot instead of a
    sequential sum; (4) the deferred cohort path may use the
    ``_zero_step_round`` version-deduplicated channel.  The wave/heap
    property suite (tests/test_wave_handlers.py) pins what survives:
    identical event multisets, per-device completion counts and per-tier
    byte totals on deterministic-latency fleets, and the liveness/byte
    invariants at scale."""

    SELECT_K = 1024   # selection width; correctness is width-independent

    supports_wave = True

    def _run_async(self, time_budget: float, max_rounds: int,
                   eval_every: int) -> List[LogEntry]:
        if self.cfg.handler_mode == "wave":
            return self._run_wave(time_budget, max_rounds, eval_every)
        table = self.devices.event_table()
        n = self.cfg.n_devices
        self._resume()
        if not self._started:
            if n:
                # one vectorized draw == the heap path's n scalar draws
                table.time[:] = self.rng.uniform(0.0, 0.05, n)
                table.seq[:] = np.arange(n)
                table.kind[:] = KIND_IDS["request"]
            self._seq = n
            self._waiting = _FifoWaiting()
            self._log(0.0)
            self._started = True
        waiting = self._waiting
        spawned: List[Tuple[float, int, str, int, Any, int]] = []
        horizon = (np.inf, np.inf)   # (time, seq) of the batch's last event

        def push(t, kind, k, payload=None, h=0):
            table.put(k, t, self._seq, kind, payload, h)
            if (t, self._seq) < horizon:
                heapq.heappush(spawned, (t, self._seq, kind, k, payload, h))
            self._seq += 1

        now = self._now
        stop = False
        while not stop:
            sel = table.select_batch(self.SELECT_K)
            if not len(sel):
                break
            ts = table.time[sel].tolist()
            ss = table.seq[sel].tolist()
            kinds = table.kind[sel].tolist()
            hs = table.h[sel].tolist()
            batch = [(ts[i], ss[i], KIND_NAMES[kinds[i]], k,
                      table.payload[k], hs[i])
                     for i, k in enumerate(sel.tolist())]
            horizon = (batch[-1][0], batch[-1][1])
            i, m = 0, len(batch)
            while i < m or spawned:
                if spawned and (i >= m or spawned[0][:2] < batch[i][:2]):
                    ev = heapq.heappop(spawned)
                else:
                    ev = batch[i]
                    i += 1
                now, _, kind, k, payload, h = ev
                if now > time_budget or self.server.t >= max_rounds:
                    # stop BEFORE clearing: the boundary event stays in the
                    # table, so a later ``run`` call / restored checkpoint
                    # resumes exactly here (the heap path peeks instead)
                    stop = True
                    break
                table.clear(k)
                if kind == "request":
                    self._handle_request(now, k, push, waiting)
                elif kind == "failure":
                    self._handle_failure(now, k, payload, push, waiting)
                else:
                    self._handle_arrival(now, k, payload, h, eval_every,
                                         push, waiting)
            spawned.clear()   # leftovers (on stop) still live in `table`
            horizon = (np.inf, np.inf)
        self._now = now
        self._log(min(now, time_budget))
        self._tail_logged = True
        return self.history

    def _handle_arrival(self, now, k, payload, h, eval_every, push,
                        waiting) -> None:
        # identical semantics to FLEngine._handle_arrival, routed through
        # the batched strategy/policy hooks (whose defaults fall back to
        # the serial hooks, keeping bit-parity)
        self.strategy.policy.observe_arrivals(
            [k], [max(0, self.server.t - h)])
        done_round, = self.strategy.on_arrivals(self, [(now, k, payload, h)])
        self.stats.completions += 1
        self.stats.completed_per_device[k] += 1
        if done_round and self.server.t % eval_every == 0:
            self._log(now)
        if self.devices.alive[k]:
            push(now, "request", k)
        self._drain_waiting(now, push, waiting)

    # -- wave mode (handler_mode="wave") -----------------------------------
    def _run_wave(self, time_budget: float, max_rounds: int,
                  eval_every: int) -> List[LogEntry]:
        """Wave event loop: same selection as the serial batched loop, but
        each maximal same-kind run of the selected batch dispatches as one
        vectorized wave (see the class docstring for the relaxed-parity
        contract).  Events spawned by a wave join the table immediately and
        interleave at the next wave *boundary*; checkpoint state is
        identical to the serial batched loop (table + waiting queue), so a
        wave run can be resumed serially and vice versa."""
        table = self.devices.event_table()
        n = self.cfg.n_devices
        self._resume()
        if not self._started:
            if n:
                table.time[:] = self.rng.uniform(0.0, 0.05, n)
                table.seq[:] = np.arange(n)
                table.kind[:] = KIND_IDS["request"]
            self._seq = n
            self._waiting = _FifoWaiting()
            self._log(0.0)
            self._started = True
        waiting = self._waiting
        # overflow heap of events spawned inside the current batch horizon:
        # (time, seq, kind_id, device, payload, h) — kind as int id so runs
        # merge against the batch's int8 kind array
        spawned: List[Tuple[float, int, int, int, Any, int]] = []
        horizon = (np.inf, np.inf)

        def push(t, kind, k, payload=None, h=0):
            table.put(k, t, self._seq, kind, payload, h)
            if (t, self._seq) < horizon:
                heapq.heappush(spawned,
                               (t, self._seq, KIND_IDS[kind], k, payload, h))
            self._seq += 1

        def push_wave(ts_w, ks_w, kind, payloads, h):
            g = len(ks_w)
            if not g:
                return
            seqs = self._seq + np.arange(g)
            self._seq += g
            table.put_wave(ks_w, ts_w, seqs, kind, payloads, h)
            # fresh seqs always exceed the horizon seq, so only a strictly
            # earlier time puts a new event inside the current batch
            kid = KIND_IDS[kind]
            for j in np.flatnonzero(ts_w < horizon[0]).tolist():
                heapq.heappush(spawned, (
                    float(ts_w[j]), int(seqs[j]), kid, int(ks_w[j]),
                    None if payloads is None else payloads[j], int(h)))

        req_id = KIND_IDS["request"]
        arr_id = KIND_IDS["arrival"]
        now = self._now
        stop = False
        while not stop:
            sel = table.select_batch(self.SELECT_K)
            if not len(sel):
                break
            ts = table.time[sel]
            ss = table.seq[sel]
            kinds = table.kind[sel]
            hs = table.h[sel]
            payloads = [table.payload[k] for k in sel.tolist()]
            horizon = (float(ts[-1]), int(ss[-1]))
            bounds = np.flatnonzero(np.diff(kinds) != 0) + 1
            i, m, b = 0, len(sel), 0
            while i < m or spawned:
                if not spawned:
                    # fast path: the next run is a contiguous batch slice
                    while b < len(bounds) and bounds[b] <= i:
                        b += 1
                    j = int(bounds[b]) if b < len(bounds) else m
                    wts, wks = ts[i:j], sel[i:j]
                    wps, whs = payloads[i:j], hs[i:j]
                    kid = int(kinds[i])
                    i = j
                else:
                    # merge the overflow heap with the batch cursor event by
                    # event until the kind changes — spawned events are the
                    # wave's own re-requests/drains, i.e. the next wave
                    rt: List[float] = []
                    rk: List[int] = []
                    rp: List[Any] = []
                    rh: List[int] = []
                    kid = -1
                    while True:
                        if spawned and (i >= m or
                                        (spawned[0][0], spawned[0][1])
                                        < (ts[i], ss[i])):
                            e = spawned[0]
                            if kid < 0:
                                kid = e[2]
                            elif e[2] != kid:
                                break
                            heapq.heappop(spawned)
                            rt.append(e[0])
                            rk.append(e[3])
                            rp.append(e[4])
                            rh.append(e[5])
                        elif i < m:
                            if kid < 0:
                                kid = int(kinds[i])
                            elif int(kinds[i]) != kid:
                                break
                            rt.append(float(ts[i]))
                            rk.append(int(sel[i]))
                            rp.append(payloads[i])
                            rh.append(int(hs[i]))
                            i += 1
                        else:
                            break
                    wts = np.asarray(rt, np.float64)
                    wks = np.asarray(rk, np.int64)
                    wps, whs = rp, np.asarray(rh, np.int64)
                if self.server.t >= max_rounds:
                    stop = True
                    break
                # budget / round-cap prefix cut: unprocessed members keep
                # their table slots, so a later ``run`` resumes exactly
                # here.  A *partial* budget cut does not end the loop —
                # the processed prefix spawns re-requests at times still
                # inside the budget, which serial order grants before
                # stopping; the drain terminates because every wave after
                # the cut point is itself cut (to zero once no spawned
                # event precedes it).  The round cap, by contrast, stops
                # the stream at the capping event exactly like the serial
                # loop's per-event ``server.t >= max_rounds`` check.
                cut = int(np.searchsorted(wts, time_budget, side="right"))
                capped = False
                if kid == arr_id:
                    srv = self.server
                    if getattr(self.strategy, "arrival_wave", False):
                        allowed = ((max_rounds - srv.t)
                                   * srv.cfg.cache_size - len(srv.cache))
                    else:
                        allowed = max_rounds - srv.t
                    if max(0, allowed) < cut:
                        cut = max(0, allowed)
                        capped = True
                if cut < len(wts):
                    stop = True
                    if not cut:
                        break
                    wts, wks = wts[:cut], wks[:cut]
                    wps, whs = wps[:cut], whs[:cut]
                table.clear_wave(wks)
                if kid == req_id:
                    self._wave_requests(wts, wks, push, push_wave, waiting)
                elif kid == arr_id:
                    self._wave_arrivals(wts, wks, wps, whs, eval_every,
                                        push, push_wave, waiting)
                else:
                    for t_f, k_f, p_f in zip(wts.tolist(), wks.tolist(),
                                             wps):
                        self._handle_failure(t_f, int(k_f), p_f, push,
                                             waiting)
                if not stop:
                    now = float(wts[-1])
                if capped:
                    break
            spawned.clear()   # leftovers (on stop) still live in `table`
            horizon = (np.inf, np.inf)
        if stop:
            # resume cursor = earliest unprocessed event, exactly where
            # the serial loops stop (they break ON that event); empty
            # slots hold +inf, so min() scans the whole table once
            rem = float(table.time.min()) if n else np.inf
            if np.isfinite(rem):
                now = rem
        self._now = now
        self._log(min(now, time_budget))
        self._tail_logged = True
        return self.history

    def _wave_requests(self, wts, wks, push, push_wave, waiting) -> None:
        """Alg. 1 Distributor over a same-kind request run: one liveness
        mask, one admission-gate slice (run members are already in event
        order, so granting the first ``free`` and parking the rest matches
        serial per-event gating), one wire-pricing pass over the wave's
        codecs, one scenario draw vector, one ``round_latency_batch`` call
        (device-index draw order) and one arrival scatter."""
        dv = self.devices
        mask = dv.alive[wks]
        if not mask.all():
            wks, wts = wks[mask], wts[mask]
        srv = self.server
        free = max(srv.cfg.max_parallel - srv.active, 0)
        if free < len(wks):
            waiting.extend(wks[free:].tolist())
            wks, wts = wks[:free], wts[:free]
        g = len(wks)
        if not g:
            return
        if not self.trainer.deferred:
            # the serial trainer's codec roundtrips interleave RNG draws
            # with the latency draws per grant — keep the scalar handler
            # (slots were already granted-gated above, but the inherited
            # handler re-checks the gate, which is a no-op here)
            for t_s, k_s in zip(wts.tolist(), wks.tolist()):
                self._handle_request(t_s, int(k_s), push, waiting)
            return
        self.stats.dispatches += g
        srv.active += g
        w_t, t0 = srv.w, srv.t
        codecs = self.strategy.channels_for(t0, wks)
        tiers = dv.tier[wks]
        # wire price once per unique codec instance: wire_bytes is
        # shape-only / value-independent, and resolve_codec caches
        # instances, so a wave usually prices one or a handful of codecs
        nbytes = np.empty(g, np.int64)
        seen: Dict[int, int] = {}
        for idx, c in enumerate(codecs):
            v = seen.get(id(c))
            if v is None:
                v = seen[id(c)] = c.wire_bytes(w_t)
            nbytes[idx] = v

        scen = self.scenario
        if scen is not None and scen.active and (
                scen.dropout_prob + scen.failure_prob > 0):
            u = self.scenario_rng.random_sample(g)
            fail = u < scen.dropout_prob + scen.failure_prob
            if fail.any():
                f = np.flatnonzero(fail)
                # failing members: down metered, failure event mid-round;
                # latency + fail-point draws in device-index order
                f = f[np.argsort(wks[f], kind="stable")]
                fks = wks[f]
                self.channel.down_wave(nbytes[f], tiers[f])
                nb = np.maximum(1, self.part_sizes[fks]
                                // self.cfg.batch_size)
                dl, cp, _ = dv.round_latency_batch(
                    fks, nbytes[f] * 8.0, np.zeros(len(f)), nb,
                    self.scenario_rng)
                fail_at = wts[f] + self.scenario_rng.uniform(
                    0.0, dl + cp, len(f))
                for j, fi in enumerate(f.tolist()):
                    push(float(fail_at[j]), "failure", int(wks[fi]),
                         "dropout" if u[fi] < scen.dropout_prob
                         else "transient")
                keep = ~fail
                wks, wts = wks[keep], wts[keep]
                nbytes, tiers = nbytes[keep], tiers[keep]
                codecs = [c for c, kp in zip(codecs, keep.tolist()) if kp]
                g = len(wks)
                if not g:
                    return

        self.channel.down_wave(nbytes, tiers)
        tasks = [self.trainer.submit(int(k), w_t, t0, c.p_s, c.p_q)
                 for k, c in zip(wks.tolist(), codecs)]
        self.channel.up_wave(nbytes, tiers)
        order = np.argsort(wks, kind="stable")   # device-index draw order
        ko = wks[order]
        bits = nbytes[order] * 8.0
        nb = np.maximum(1, self.part_sizes[ko] // self.cfg.batch_size)
        dl, cp, ul = dv.round_latency_batch(ko, bits, bits, nb, self.rng)
        push_wave(wts[order] + dl + cp + ul, ko, "arrival",
                  [tasks[idx] for idx in order.tolist()], t0)

    def _wave_arrivals(self, wts, wks, wps, whs, eval_every, push,
                       push_wave, waiting, push_wave_free=None,
                       max_rounds=None) -> None:
        """Alg. 2 Receiver/Updater over a same-kind arrival run.  TEA-family
        strategies (``arrival_wave=True``) fuse the cache inserts and the
        Eqs. 6-10 aggregation via ``on_arrivals``/``receive_many``,
        processed in segments that end exactly at cache-fill boundaries so
        each eval log observes the same server round/state as the serial
        path.  Other strategies keep the bit-faithful scalar handler.

        ``push_wave_free`` routes the re-request scatter (a multi-task
        fleet hands requests back unassigned, task=-1); ``max_rounds``,
        when given, truncates the run at the round cap and *drops* the
        excess arrivals — the fleet semantics, where a finished job's
        in-flight events are consumed and ignored while other jobs keep
        running (the single-task loop instead cuts at the cap and leaves
        the excess scheduled)."""
        srv = self.server
        strategy = self.strategy
        fused = getattr(strategy, "arrival_wave", False)
        if max_rounds is not None:
            allowed = ((max_rounds - srv.t) * srv.cfg.cache_size
                       - len(srv.cache)) if fused else max_rounds - srv.t
            allowed = max(0, allowed)
            if allowed < len(wks):
                wts, wks = wts[:allowed], wks[:allowed]
                wps, whs = wps[:allowed], whs[:allowed]
        g = len(wks)
        if not g:
            return
        if not fused or (g == 1 and push_wave_free is None):
            for idx in range(g):
                self._handle_arrival(float(wts[idx]), int(wks[idx]),
                                     wps[idx], int(whs[idx]), eval_every,
                                     push, waiting)
            return
        K = srv.cfg.cache_size
        t0, c0 = srv.t, len(srv.cache)
        # staleness of arrival idx as the serial loop would observe it:
        # t has advanced by one per preceding cache fill
        stal = np.maximum(0, t0 + (c0 + np.arange(g)) // K - whs)
        strategy.policy.observe_arrivals(wks.tolist(), stal.tolist())
        ks_l, hs_l = wks.tolist(), whs.tolist()
        arrivals = [(float(wts[idx]), ks_l[idx], wps[idx], hs_l[idx])
                    for idx in range(g)]
        start = 0
        while start < g:
            seg_end = min(g, start + (K - len(srv.cache)))
            dones = strategy.on_arrivals(self, arrivals[start:seg_end])
            if dones[-1] and srv.t % eval_every == 0:
                self._log(float(wts[seg_end - 1]))
            start = seg_end
        self.stats.completions += g
        np.add.at(self.stats.completed_per_device, wks, 1)
        alive = self.devices.alive[wks]
        # a fleet hands freed devices back to its assigner (task=-1)
        (push_wave_free or push_wave)(wts[alive], wks[alive],
                                      "request", None, 0)
        # one-slice drain vs. the serial loop's per-arrival pops; drained
        # request j fires at arrival j's own timestamp, matching the slot
        # release order a serial drain would produce
        n_drain = min(len(waiting), max(0, srv.cfg.max_parallel
                                        - srv.active))
        if n_drain:
            drained = np.asarray(waiting.pop_many(n_drain), np.int64)
            push_wave(wts[:n_drain], drained, "request", None, 0)

    # -- checkpoint/resume: EventTable instead of the heap -----------------
    def _sched_state(self, reg) -> Dict[str, Any]:
        tab = self.devices.events
        table = None
        if tab is not None:
            live = np.flatnonzero(tab.time < np.inf).tolist()
            table = {"slots": [[int(k), float(tab.time[k]), int(tab.seq[k]),
                                int(tab.kind[k]), int(tab.h[k]),
                                int(tab.task[k]),
                                self._pack_payload(tab.payload[k], reg)]
                               for k in live]}
        waiting = (None if self._waiting is None
                   else [int(x) for x in
                         self._waiting._items[self._waiting._head:]])
        return {"table": table, "waiting": waiting}

    def _load_sched(self, st, pts: List[PendingTask]) -> None:
        if st["table"] is not None:
            tab = self.devices.event_table()
            tab.time[:] = np.inf
            tab.payload = [None] * len(tab.time)
            for k, t, seq, kind, h, task, p in st["table"]["slots"]:
                k = int(k)
                tab.time[k] = float(t)
                tab.seq[k] = int(seq)
                tab.kind[k] = int(kind)
                tab.h[k] = int(h)
                tab.task[k] = int(task)
                tab.payload[k] = self._unpack_payload(p, pts)
        if st["waiting"] is None:
            self._waiting = None
        else:
            w = _FifoWaiting()
            w._items = [int(x) for x in st["waiting"]]
            self._waiting = w


# scheduler registry: SimConfig.scheduler -> engine class (the same
# one-subclass-plus-one-entry idiom as STRATEGIES / CODECS / POLICIES)
SCHEDULERS: Dict[str, type] = {"heap": FLEngine, "batched": BatchedEngine}
