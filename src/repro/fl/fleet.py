"""Multi-task fleet: N concurrent FL jobs over ONE shared device fleet.

FedAST (arXiv:2406.00302) makes the systems case this module reproduces:
when several federated jobs train *simultaneously* over the same device
population, a shared asynchronous event loop with per-job buffers beats
running the jobs back-to-back — idle capacity one job leaves on the table
(its admission gate full, its model mid-flight) is immediately usable by
another, and dynamically steering devices toward the slower-converging
jobs trims the straggler job's wall-clock without starving the rest.

Mapping to that design (and back to TEASQ-Fed, the per-job protocol):

* **One fleet, many jobs** — :class:`MultiTaskEngine` holds a single
  shared :class:`~repro.fl.engine.DeviceRegistry` (one draw of link rates
  and compute coefficients, one liveness array, one tier map) and ONE
  virtual-clock event loop, while each task ``j`` keeps its own complete
  per-job state: a :class:`~repro.core.server.TeasqServer` (so each job
  runs its own Alg. 1 C-fraction admission gate and Alg. 2
  staleness-weighted cache), a :class:`~repro.fl.protocols.ProtocolStrategy`
  + :class:`~repro.fl.policies.CodecPolicy` pair, a
  :class:`~repro.fl.engine.ChannelMeter` (exact per-job wire bytes), a
  trainer, and a waiting queue.  Per-task state lives in a full
  :class:`~repro.fl.engine.FLEngine` built in *shared-fleet mode* (RNG,
  registry and scenario stream injected), so every handler — dispatch,
  scenario failures, codec routing, Eqs. 6-10 aggregation — is the
  single-task code, verbatim.
* **Device→job assignment** — FedAST's routing step.  A device's request
  event carries ``task = -1`` ("assign on handling"); the bound
  :class:`Assigner` (registry :data:`ASSIGNERS`) picks the job at grant
  time.  ``round_robin`` cycles jobs; ``weighted`` statically partitions
  the fleet by ``FleetConfig.shares`` (the fixed-allocation baseline);
  ``adaptive`` reallocates grant probability toward slower-converging
  jobs — it samples jobs with open admission slots with probability
  proportional to their current loss proxy (``1 - accuracy`` from each
  server's recorded curve), FedAST's dynamic reallocation in one rule.
  Assigners draw from a dedicated RNG stream, so assignment never
  perturbs the shared engine stream.
* **Both schedulers** — the fleet event loop comes in the same two
  flavors as the single-task engine (``FleetConfig.scheduler``): the
  reference heap (events ``(t, seq, kind, k, task, payload, h)``) and the
  batched :class:`~repro.fl.engine.EventTable` path, whose resident
  ``task`` column carries job ownership through the fused next-K
  selection.  A degenerate single-task fleet replays the standalone
  engine's RNG draws in the exact same order on either scheduler, so its
  history is bit-identical to ``FLEngine`` / ``BatchedEngine`` —
  tests/test_fleet.py pins this against tests/data/pinned_histories.json.

Checkpoint/resume: :meth:`MultiTaskEngine.state_dict` serializes the
shared pieces once (RNG streams, registry, event queue/table, assigner)
plus every per-task core (server cache, policy EWMAs, history, deferred
cohort buffers) — same plain-ndarray format as ``FLEngine.state_dict``,
round-trippable through ``repro.checkpoint.io.save_blob``.
"""
from __future__ import annotations

import abc
import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.fl.engine import (KIND_IDS, KIND_NAMES, SCHEDULERS, _FifoWaiting,
                             DeviceRegistry, _load_rng, _pack_rng)
from repro.fl.simulator import (ComputeConfig, LogEntry, ScenarioConfig,
                                SimConfig, WirelessConfig)


# ----------------------------------------------------------------------
# Fleet configuration
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """N per-task protocol specs sharing one physical fleet.

    Each entry of ``tasks`` is a full :class:`SimConfig` describing that
    job's protocol knobs (method, task/model family, c_fraction, codec,
    policy, cohort size, ...).  The *fleet-level* fields below override the
    per-task ones that describe shared physics — every job sees the same
    devices, links, tiers and seed, so ``resolve(i)`` rewrites
    ``n_devices`` / ``seed`` / ``scheduler`` / ``scenario`` / ``wireless``
    / ``compute`` on task ``i``'s spec."""

    tasks: Sequence[SimConfig]
    n_devices: int = 100
    seed: int = 0
    scheduler: str = "heap"
    # "serial" (bit-identical per-event loop) or "wave" (task-id-aware
    # vectorized waves; needs scheduler="batched") — the fleet-level analog
    # of SimConfig.handler_mode, rewritten onto every per-task spec so the
    # runtimes' wave-gated paths (e.g. the cohort zero-step fast path)
    # agree with the fleet loop
    handler_mode: str = "serial"
    assigner: str = "round_robin"
    shares: Optional[Sequence[float]] = None     # weighted assigner only
    scenario: Optional[ScenarioConfig] = None
    wireless: WirelessConfig = dataclasses.field(default_factory=WirelessConfig)
    compute: ComputeConfig = dataclasses.field(default_factory=ComputeConfig)

    def resolve(self, i: int) -> SimConfig:
        return dataclasses.replace(
            self.tasks[i], n_devices=self.n_devices, seed=self.seed,
            scheduler=self.scheduler, handler_mode=self.handler_mode,
            scenario=self.scenario,
            wireless=self.wireless, compute=self.compute)


# ----------------------------------------------------------------------
# Device -> task assigners
# ----------------------------------------------------------------------
class Assigner(abc.ABC):
    """Picks which job a device's request event serves.  ``assign`` sees
    the requesting device id and the list of live (unfinished) task
    indices — never empty; the fleet loop stops before calling in.  Any
    randomness comes from a dedicated seeded stream so assignment leaves
    the shared engine RNG untouched (which is what keeps a single-task
    fleet bit-identical to the standalone engine)."""

    name: str = ""

    def __init__(self, fleet: "MultiTaskEngine"):
        self.fleet = fleet
        self.rng = np.random.RandomState(
            (fleet.cfg.seed + 0xA551C4E) % (2 ** 31))

    @abc.abstractmethod
    def assign(self, k: int, live: Sequence[int]) -> int:
        """Task index for device ``k``'s request, drawn from ``live``."""

    def state_dict(self) -> Dict[str, Any]:
        return {"rng": _pack_rng(self.rng)}

    def load_state(self, state: Dict[str, Any]) -> None:
        _load_rng(self.rng, state["rng"])


class RoundRobinAssigner(Assigner):
    """Cycle requests through the live jobs in order — draws no RNG, so a
    single-task fleet stays on the standalone engine's exact stream."""

    name = "round_robin"

    def __init__(self, fleet):
        super().__init__(fleet)
        self._next = 0

    def assign(self, k, live):
        j = live[self._next % len(live)]
        self._next += 1
        return j

    def state_dict(self):
        st = super().state_dict()
        st["next"] = int(self._next)
        return st

    def load_state(self, state):
        super().load_state(state)
        self._next = int(state["next"])


class WeightedAssigner(Assigner):
    """Static fleet partition: device ``k`` always serves the job its
    contiguous share block maps to (``FleetConfig.shares``, normalized;
    uniform when unset) — the fixed-allocation baseline FedAST's dynamic
    routing is measured against.  Requests whose home job has finished
    fall back to cycling the remaining live jobs."""

    name = "weighted"

    def __init__(self, fleet):
        super().__init__(fleet)
        n, t = fleet.cfg.n_devices, len(fleet.cfg.tasks)
        shares = np.asarray(fleet.cfg.shares if fleet.cfg.shares is not None
                            else [1.0] * t, float)
        assert len(shares) == t and (shares >= 0).all() and shares.sum() > 0
        bounds = np.floor(np.cumsum(shares / shares.sum()) * n + 0.5)
        self._map = np.searchsorted(bounds, np.arange(n), side="right")
        self._map = np.minimum(self._map, t - 1).astype(np.int64)
        self._next = 0

    def assign(self, k, live):
        j = int(self._map[k])
        if j in live:
            return j
        j = live[self._next % len(live)]
        self._next += 1
        return j

    def state_dict(self):
        st = super().state_dict()
        st["next"] = int(self._next)
        return st

    def load_state(self, state):
        super().load_state(state)
        self._next = int(state["next"])


class AdaptiveAssigner(Assigner):
    """FedAST-style dynamic reallocation: grant probability shifts toward
    the slower-converging jobs.  Candidates are the live jobs with a free
    Alg. 1 admission slot (all live jobs when everyone is saturated); a
    request is routed to candidate ``j`` with probability proportional to
    its loss proxy ``max(floor, 1 - accuracy)`` read off the job's own
    recorded curve — a job near convergence stops attracting devices and
    its capacity flows to whoever still needs rounds."""

    name = "adaptive"
    floor = 0.05      # keeps converged jobs reachable (and p well-defined)

    def assign(self, k, live):
        rts = self.fleet.runtimes
        cand = [j for j in live
                if rts[j].server.active < rts[j].server.cfg.max_parallel]
        if not cand:
            cand = list(live)
        if len(cand) == 1:
            return cand[0]
        w = np.asarray([max(self.floor, 1.0 - rts[j].history[-1].accuracy)
                        for j in cand])
        return cand[int(self.rng.choice(len(cand), p=w / w.sum()))]


ASSIGNERS: Dict[str, Type[Assigner]] = {
    cls.name: cls for cls in (RoundRobinAssigner, WeightedAssigner,
                              AdaptiveAssigner)
}


def make_assigner(name: str, fleet: "MultiTaskEngine") -> Assigner:
    try:
        return ASSIGNERS[name](fleet)
    except KeyError:
        raise ValueError(f"unknown assigner {name!r}; "
                         f"expected one of {sorted(ASSIGNERS)}") from None


# ----------------------------------------------------------------------
# The fleet engine
# ----------------------------------------------------------------------
class MultiTaskEngine:
    """Run ``len(cfg.tasks)`` concurrent FL jobs over one shared fleet.

    ``datas`` / ``partitions`` / ``w_inits`` are per-task lists aligned
    with ``cfg.tasks`` (see :func:`build_fleet` for the one-call
    constructor).  Each job is a full per-task engine runtime sharing the
    fleet's RNG stream, :class:`DeviceRegistry` and scenario stream; the
    fleet owns the event loop and drives the runtimes' own handlers, so
    all protocol behavior is the single-task code."""

    def __init__(self, datas: Sequence[Dict[str, np.ndarray]],
                 partitions: Sequence[List[np.ndarray]],
                 w_inits: Sequence[Any], cfg: FleetConfig):
        if not cfg.tasks:
            raise ValueError("FleetConfig.tasks is empty")
        assert len(datas) == len(partitions) == len(w_inits) == len(cfg.tasks)
        try:
            engine_cls = SCHEDULERS[cfg.scheduler]
        except KeyError:
            raise ValueError(
                f"unknown scheduler {cfg.scheduler!r}; "
                f"expected one of {sorted(SCHEDULERS)}") from None
        self.cfg = cfg
        # shared physics: ONE engine-ordered RNG draw (rates, then a_k —
        # identical to a standalone engine with the same seed), one
        # registry, one scenario stream, tiers applied once
        self.rng = np.random.RandomState(cfg.seed)
        self.devices = DeviceRegistry(cfg.resolve(0), self.rng)
        self.scenario_rng = np.random.RandomState(
            (cfg.seed + 0x5CE7A710) % (2 ** 31))
        if cfg.scenario is not None and cfg.scenario.tiers:
            self.devices.apply_tiers(cfg.scenario.tiers)
        self.runtimes = []
        for i in range(len(cfg.tasks)):
            rt = engine_cls(datas[i], partitions[i], w_inits[i],
                            cfg.resolve(i), rng=self.rng,
                            devices=self.devices,
                            scenario_rng=self.scenario_rng)
            if not rt.strategy.event_driven:
                raise ValueError(
                    f"fleet task {i} ({cfg.tasks[i].method!r}) is not "
                    "event-driven; synchronous protocols cannot share the "
                    "fleet event loop")
            self.runtimes.append(rt)
        self.assigner = make_assigner(cfg.assigner, self)
        self.waiting: List[Any] = []          # per-task, built at start
        self._started = False
        self._now = 0.0
        self._seq = 0
        self._events: Optional[List[Tuple]] = None     # heap scheduler

    # -- helpers -----------------------------------------------------------
    def _live(self, max_rounds: int) -> List[int]:
        return [j for j, rt in enumerate(self.runtimes)
                if rt.server.t < max_rounds]

    def _resume(self) -> None:
        for rt in self.runtimes:
            rt._resume()

    def _finish(self, now: float, time_budget: float) -> List[List[LogEntry]]:
        self._now = now
        for rt in self.runtimes:
            rt._log(min(now, time_budget))
            rt._tail_logged = True
        return [rt.history for rt in self.runtimes]

    # -- entry point -------------------------------------------------------
    def run(self, time_budget: float = 300.0, max_rounds: int = 10 ** 9,
            eval_every: int = 1) -> List[List[LogEntry]]:
        """Advance the shared virtual clock; returns the per-task histories
        (aligned with ``cfg.tasks``).  Resumable exactly like
        ``FLEngine.run``: a second call picks up at the stop boundary and
        ``run(t)`` + ``run(T)`` matches ``run(T)`` bit-for-bit."""
        if self.cfg.scheduler == "batched":
            if self.cfg.handler_mode == "wave":
                return self._run_wave(time_budget, max_rounds, eval_every)
            return self._run_batched(time_budget, max_rounds, eval_every)
        return self._run_heap(time_budget, max_rounds, eval_every)

    # -- heap scheduler ----------------------------------------------------
    def _push(self, t, kind, k, task, payload=None, h=0):
        heapq.heappush(self._events,
                       (t, self._seq, kind, k, task, payload, h))
        self._seq += 1

    def _task_pusher(self, j: int):
        """A single-task-engine-shaped ``push`` bound to job ``j`` — what
        the runtimes' inherited handlers call, so arrivals, scenario
        failures, retries and waiting-queue drains all stay job-bound."""
        return lambda t, kind, k, payload=None, h=0: \
            self._push(t, kind, k, j, payload, h)

    def _run_heap(self, time_budget, max_rounds, eval_every):
        self._resume()
        if not self._started:
            self._events = []
            self.waiting = [[] for _ in self.runtimes]
            for k in range(self.cfg.n_devices):
                # same per-device scalar draws, same order, as the
                # standalone engine's initial burst
                self._push(self.rng.uniform(0, 0.05), "request", k, -1)
            for rt in self.runtimes:
                rt._log(0.0)
                rt._started = True
            self._started = True
        events = self._events
        pushers = [self._task_pusher(j) for j in range(len(self.runtimes))]
        now = self._now
        while events:
            live = self._live(max_rounds)
            t_next = events[0][0]
            if t_next > time_budget or not live:
                now = t_next      # peek: boundary event stays queued
                break
            now, _, kind, k, task, payload, h = heapq.heappop(events)
            if kind == "request":
                if task < 0 or self.runtimes[task].server.t >= max_rounds:
                    task = self.assigner.assign(k, live)
                self.runtimes[task]._handle_request(
                    now, k, pushers[task], self.waiting[task])
            elif self.runtimes[task].server.t >= max_rounds:
                continue          # drop in-flight events of a finished job
            elif kind == "failure":
                self.runtimes[task]._handle_failure(
                    now, k, payload, pushers[task], self.waiting[task])
            else:
                self._on_arrival(task, now, k, payload, h, eval_every,
                                 pushers[task])
        return self._finish(now, time_budget)

    def _on_arrival(self, j, now, k, payload, h, eval_every, push_j,
                    batched: bool = False) -> None:
        # mirrors FLEngine._handle_arrival / BatchedEngine._handle_arrival,
        # except the re-request goes out unassigned (task = -1) so the
        # assigner routes the freed device on its next grant
        rt = self.runtimes[j]
        stale = max(0, rt.server.t - h)
        if batched:
            rt.strategy.policy.observe_arrivals([k], [stale])
            done_round, = rt.strategy.on_arrivals(rt, [(now, k, payload, h)])
        else:
            rt.strategy.policy.observe_arrival(k, stale)
            done_round = rt.strategy.on_arrival(rt, now, k, payload, h)
        rt.stats.completions += 1
        rt.stats.completed_per_device[k] += 1
        if done_round and rt.server.t % eval_every == 0:
            rt._log(now)
        if self.devices.alive[k]:
            self._push_free(now, "request", k)
        rt._drain_waiting(now, push_j, self.waiting[j])

    def _push_free(self, t, kind, k):
        self._push(t, kind, k, -1)

    # -- batched scheduler -------------------------------------------------
    def _run_batched(self, time_budget, max_rounds, eval_every):
        table = self.devices.event_table()
        n = self.cfg.n_devices
        self._resume()
        if not self._started:
            if n:
                table.time[:] = self.rng.uniform(0.0, 0.05, n)
                table.seq[:] = np.arange(n)
                table.kind[:] = KIND_IDS["request"]
                table.task[:] = -1
            self._seq = n
            self.waiting = [_FifoWaiting() for _ in self.runtimes]
            for rt in self.runtimes:
                rt._log(0.0)
                rt._started = True
            self._started = True
        spawned: List[Tuple] = []
        horizon = [(np.inf, np.inf)]   # (time, seq) of the batch's last event

        def make_push(j):
            def push(t, kind, k, payload=None, h=0):
                table.put(k, t, self._seq, kind, payload, h, task=j)
                if (t, self._seq) < horizon[0]:
                    heapq.heappush(spawned,
                                   (t, self._seq, kind, k, j, payload, h))
                self._seq += 1
            return push

        pushers = [make_push(j) for j in range(len(self.runtimes))]
        push_free = make_push(-1)
        self._push_free = lambda t, kind, k: push_free(t, kind, k)

        select_k = SCHEDULERS["batched"].SELECT_K
        now = self._now
        stop = False
        while not stop:
            sel = table.select_batch(select_k)
            if not len(sel):
                break
            ts = table.time[sel].tolist()
            ss = table.seq[sel].tolist()
            kinds = table.kind[sel].tolist()
            hs = table.h[sel].tolist()
            tks = table.task[sel].tolist()
            batch = [(ts[i], ss[i], KIND_NAMES[kinds[i]], k, tks[i],
                      table.payload[k], hs[i])
                     for i, k in enumerate(sel.tolist())]
            horizon[0] = (batch[-1][0], batch[-1][1])
            i, m = 0, len(batch)
            while i < m or spawned:
                if spawned and (i >= m or spawned[0][:2] < batch[i][:2]):
                    ev = heapq.heappop(spawned)
                else:
                    ev = batch[i]
                    i += 1
                now, _, kind, k, task, payload, h = ev
                live = self._live(max_rounds)
                if now > time_budget or not live:
                    stop = True   # boundary event stays in the table
                    break
                table.clear(k)
                if kind == "request":
                    if task < 0 or \
                            self.runtimes[task].server.t >= max_rounds:
                        task = self.assigner.assign(k, live)
                    self.runtimes[task]._handle_request(
                        now, k, pushers[task], self.waiting[task])
                elif self.runtimes[task].server.t >= max_rounds:
                    continue
                elif kind == "failure":
                    self.runtimes[task]._handle_failure(
                        now, k, payload, pushers[task], self.waiting[task])
                else:
                    self._on_arrival(task, now, k, payload, h, eval_every,
                                     pushers[task], batched=True)
            spawned.clear()
            horizon[0] = (np.inf, np.inf)
        del self._push_free        # restore the heap-path instance method
        return self._finish(now, time_budget)

    # -- wave scheduler (handler_mode="wave") ------------------------------
    def _run_wave(self, time_budget, max_rounds, eval_every):
        """Task-id-aware wave loop: the single-task wave machinery
        (``BatchedEngine._run_wave``) with the task column carried through.
        Same-kind runs are selected exactly like the serial batched loop,
        then partitioned per task id — unassigned requests (task=-1, and
        requests whose job already finished) are routed through the
        stateful assigner in event order first, so assignment decisions
        match the serial loop; each per-task sub-wave then dispatches
        through that runtime's ``_wave_requests`` / ``_wave_arrivals``.
        Cross-task ordering *within* one run is relaxed (sub-waves run in
        ascending task id, not interleaved event order) — task state is
        disjoint per runtime, so only the shared RNG/scenario draw order
        differs, which is already part of the wave contract.  A finished
        job's in-flight arrivals are consumed and dropped, exactly like the
        serial loops."""
        table = self.devices.event_table()
        n = self.cfg.n_devices
        self._resume()
        if not self._started:
            if n:
                table.time[:] = self.rng.uniform(0.0, 0.05, n)
                table.seq[:] = np.arange(n)
                table.kind[:] = KIND_IDS["request"]
                table.task[:] = -1
            self._seq = n
            self.waiting = [_FifoWaiting() for _ in self.runtimes]
            for rt in self.runtimes:
                rt._log(0.0)
                rt._started = True
            self._started = True
        # (time, seq, kind_id, device, task, payload, h)
        spawned: List[Tuple] = []
        horizon = [(np.inf, np.inf)]

        def make_push(j):
            def push(t, kind, k, payload=None, h=0):
                table.put(k, t, self._seq, kind, payload, h, task=j)
                if (t, self._seq) < horizon[0]:
                    heapq.heappush(spawned, (t, self._seq, KIND_IDS[kind],
                                             k, j, payload, h))
                self._seq += 1
            return push

        def make_push_wave(j):
            def push_wave(ts_w, ks_w, kind, payloads, h):
                g = len(ks_w)
                if not g:
                    return
                seqs = self._seq + np.arange(g)
                self._seq += g
                table.put_wave(ks_w, ts_w, seqs, kind, payloads, h, task=j)
                kid = KIND_IDS[kind]
                for w in np.flatnonzero(ts_w < horizon[0][0]).tolist():
                    heapq.heappush(spawned, (
                        float(ts_w[w]), int(seqs[w]), kid, int(ks_w[w]), j,
                        None if payloads is None else payloads[w], int(h)))
            return push_wave

        pushers = [make_push(j) for j in range(len(self.runtimes))]
        wavers = [make_push_wave(j) for j in range(len(self.runtimes))]
        push_free = make_push(-1)
        push_free_wave = make_push_wave(-1)
        self._push_free = lambda t, kind, k: push_free(t, kind, k)

        req_id, arr_id = KIND_IDS["request"], KIND_IDS["arrival"]
        select_k = SCHEDULERS["batched"].SELECT_K
        now = self._now
        stop = False
        while not stop:
            sel = table.select_batch(select_k)
            if not len(sel):
                break
            ts = table.time[sel]
            ss = table.seq[sel]
            kinds = table.kind[sel]
            hs = table.h[sel]
            tks = table.task[sel]
            payloads = [table.payload[k] for k in sel.tolist()]
            horizon[0] = (float(ts[-1]), int(ss[-1]))
            bounds = np.flatnonzero(np.diff(kinds) != 0) + 1
            i, m, b = 0, len(sel), 0
            while i < m or spawned:
                if not spawned:
                    while b < len(bounds) and bounds[b] <= i:
                        b += 1
                    j_end = int(bounds[b]) if b < len(bounds) else m
                    wts, wks = ts[i:j_end], sel[i:j_end]
                    wtk, whs = tks[i:j_end], hs[i:j_end]
                    wps = payloads[i:j_end]
                    kid = int(kinds[i])
                    i = j_end
                else:
                    rt_l: List[float] = []
                    rk_l: List[int] = []
                    rj_l: List[int] = []
                    rp_l: List[Any] = []
                    rh_l: List[int] = []
                    kid = -1
                    while True:
                        if spawned and (i >= m or
                                        (spawned[0][0], spawned[0][1])
                                        < (ts[i], ss[i])):
                            e = spawned[0]
                            if kid < 0:
                                kid = e[2]
                            elif e[2] != kid:
                                break
                            heapq.heappop(spawned)
                            rt_l.append(e[0])
                            rk_l.append(e[3])
                            rj_l.append(e[4])
                            rp_l.append(e[5])
                            rh_l.append(e[6])
                        elif i < m:
                            if kid < 0:
                                kid = int(kinds[i])
                            elif int(kinds[i]) != kid:
                                break
                            rt_l.append(float(ts[i]))
                            rk_l.append(int(sel[i]))
                            rj_l.append(int(tks[i]))
                            rp_l.append(payloads[i])
                            rh_l.append(int(hs[i]))
                            i += 1
                        else:
                            break
                    wts = np.asarray(rt_l, np.float64)
                    wks = np.asarray(rk_l, np.int64)
                    wtk = np.asarray(rj_l, np.int64)
                    wps, whs = rp_l, np.asarray(rh_l, np.int64)
                live = self._live(max_rounds)
                if not live:
                    stop = True
                    break
                # partial budget cut: keep draining — the prefix spawns
                # re-requests still inside the budget, which serial order
                # grants before stopping (see BatchedEngine._run_wave)
                cut = int(np.searchsorted(wts, time_budget, side="right"))
                if cut < len(wts):
                    stop = True
                    if not cut:
                        break
                    wts, wks, wtk = wts[:cut], wks[:cut], wtk[:cut]
                    wps, whs = wps[:cut], whs[:cut]
                table.clear_wave(wks)
                if kid == req_id:
                    wtk = np.asarray(wtk, np.int64).copy()
                    for idx in range(len(wtk)):
                        tj = int(wtk[idx])
                        if tj < 0 or \
                                self.runtimes[tj].server.t >= max_rounds:
                            wtk[idx] = self.assigner.assign(int(wks[idx]),
                                                            live)
                    for tj in np.unique(wtk).tolist():
                        s = wtk == tj
                        self.runtimes[tj]._wave_requests(
                            wts[s], wks[s], pushers[tj], wavers[tj],
                            self.waiting[tj])
                elif kid == arr_id:
                    for tj in np.unique(wtk).tolist():
                        rt = self.runtimes[tj]
                        if rt.server.t >= max_rounds:
                            continue     # consumed + dropped, like serial
                        s = wtk == tj
                        sub_ps = [p for p, mm in zip(wps, s.tolist()) if mm]
                        if getattr(rt.strategy, "arrival_wave", False):
                            rt._wave_arrivals(
                                wts[s], wks[s], sub_ps, whs[s], eval_every,
                                pushers[tj], wavers[tj], self.waiting[tj],
                                push_wave_free=push_free_wave,
                                max_rounds=max_rounds)
                        else:
                            sis = np.flatnonzero(s).tolist()
                            for idx in sis:
                                if rt.server.t >= max_rounds:
                                    break
                                self._on_arrival(
                                    tj, float(wts[idx]), int(wks[idx]),
                                    wps[idx], int(whs[idx]), eval_every,
                                    pushers[tj], batched=True)
                else:
                    for idx in range(len(wks)):
                        tj = int(wtk[idx])
                        if self.runtimes[tj].server.t >= max_rounds:
                            continue
                        self.runtimes[tj]._handle_failure(
                            float(wts[idx]), int(wks[idx]), wps[idx],
                            pushers[tj], self.waiting[tj])
                if not stop:
                    now = float(wts[-1])
            spawned.clear()
            horizon[0] = (np.inf, np.inf)
        if stop:
            # resume cursor = earliest unprocessed event (serial loops
            # break ON that event); empty slots hold +inf
            rem = float(table.time.min()) if n else np.inf
            if np.isfinite(rem):
                now = rem
        del self._push_free
        return self._finish(now, time_budget)

    # -- checkpoint/resume -------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Full fleet state: the shared pieces once (RNG streams, registry,
        event queue/table, per-task waiting queues, assigner) plus each
        runtime's core (``FLEngine._core_state``) and deferred cohort
        buffers.  Same plain-ndarray format as ``FLEngine.state_dict`` —
        feed to ``repro.checkpoint.io.save_blob``; restore with
        :meth:`load_state` on a freshly built identical fleet."""
        regs = [({}, []) for _ in self.runtimes]
        dv = self.devices
        state = {
            "version": 1,
            "rng": _pack_rng(self.rng),
            "scenario_rng": _pack_rng(self.scenario_rng),
            "devices": {"down_rates": np.asarray(dv.down_rates),
                        "up_rates": np.asarray(dv.up_rates),
                        "a_k": np.asarray(dv.a_k),
                        "phi_k": np.asarray(dv.phi_k),
                        "alive": np.asarray(dv.alive),
                        "tier": np.asarray(dv.tier)},
            "started": bool(self._started),
            "now": float(self._now),
            "seq": int(self._seq),
            "assigner": self.assigner.state_dict(),
            "tasks": [rt._core_state(regs[j])
                      for j, rt in enumerate(self.runtimes)],
        }
        if self.cfg.scheduler == "batched":
            tab, table = self.devices.events, None
            if tab is not None:
                live = np.flatnonzero(tab.time < np.inf).tolist()
                table = [[int(k), float(tab.time[k]), int(tab.seq[k]),
                          int(tab.kind[k]), int(tab.h[k]), int(tab.task[k]),
                          self._pack_ev_payload(int(tab.task[k]),
                                                tab.payload[k], regs)]
                         for k in live]
            state["sched"] = {"table": table}
            state["waiting"] = [[int(x) for x in w._items[w._head:]]
                                for w in self.waiting]
        else:
            events = None
            if self._events is not None:
                events = [[float(t), int(s), kind, int(k), int(j),
                           self._pack_ev_payload(int(j), p, regs), int(h)]
                          for t, s, kind, k, j, p, h in self._events]
            state["sched"] = {"events": events}
            state["waiting"] = [[int(x) for x in w] for w in self.waiting]
        state["pending"] = [rt._pack_pending(regs[j])
                            for j, rt in enumerate(self.runtimes)]
        return state

    def _pack_ev_payload(self, j: int, payload: Any, regs) -> List[Any]:
        # unassigned (task = -1) events are requests with no payload; route
        # them through runtime 0's packer for a well-formed ["none"] tag
        j = max(j, 0)
        return self.runtimes[j]._pack_payload(payload, regs[j])

    def load_state(self, state: Dict[str, Any]) -> None:
        if int(state["version"]) != 1:
            raise ValueError(
                f"unknown fleet checkpoint version {state['version']!r}")
        _load_rng(self.rng, state["rng"])
        _load_rng(self.scenario_rng, state["scenario_rng"])
        dv, d = self.devices, state["devices"]
        dv.down_rates[:] = np.asarray(d["down_rates"])
        dv.up_rates[:] = np.asarray(d["up_rates"])
        dv.a_k[:] = np.asarray(d["a_k"])
        dv.phi_k[:] = np.asarray(d["phi_k"])
        dv.alive[:] = np.asarray(d["alive"], bool)
        dv.tier[:] = np.asarray(d["tier"])
        self._started = bool(state["started"])
        self._now = float(state["now"])
        self._seq = int(state["seq"])
        self.assigner.load_state(state["assigner"])
        ptss = [rt._unpack_pending(state["pending"][j])
                for j, rt in enumerate(self.runtimes)]
        for j, rt in enumerate(self.runtimes):
            rt._load_core(state["tasks"][j], ptss[j])
        if self.cfg.scheduler == "batched":
            tab = self.devices.event_table()
            tab.time[:] = np.inf
            tab.payload = [None] * len(tab.time)
            if state["sched"]["table"] is not None:
                for k, t, seq, kind, h, task, p in state["sched"]["table"]:
                    k, task = int(k), int(task)
                    tab.time[k] = float(t)
                    tab.seq[k] = int(seq)
                    tab.kind[k] = int(kind)
                    tab.h[k] = int(h)
                    tab.task[k] = task
                    tab.payload[k] = self._unpack_ev_payload(task, p, ptss)
            self.waiting = []
            for items in state["waiting"]:
                w = _FifoWaiting()
                w._items = [int(x) for x in items]
                self.waiting.append(w)
        else:
            ev = state["sched"]["events"]
            self._events = None if ev is None else [
                (float(t), int(s), str(kind), int(k), int(j),
                 self._unpack_ev_payload(int(j), p, ptss), int(h))
                for t, s, kind, k, j, p, h in ev]
            self.waiting = [[int(x) for x in w] for w in state["waiting"]]

    def _unpack_ev_payload(self, j: int, packed, ptss) -> Any:
        j = max(j, 0)
        return self.runtimes[j]._unpack_payload(packed, ptss[j])


def build_fleet(cfg: FleetConfig, *, iid: bool = True, n_train: int = 600,
                n_test: int = 200) -> MultiTaskEngine:
    """One-call fleet constructor: synthesizes each task's (data,
    partitions, w0) via ``repro.fl.protocols.make_setup`` (per-task data
    seeds offset by the task index so jobs do not share datasets) and
    builds the :class:`MultiTaskEngine`."""
    from repro.fl.protocols import make_setup
    datas, parts, w0s = [], [], []
    for i in range(len(cfg.tasks)):
        spec = cfg.resolve(i)
        data, p, w0 = make_setup(cfg.n_devices, iid, cfg.seed + i,
                                 n_train, n_test, spec.task)
        datas.append(data)
        parts.append(p)
        w0s.append(w0)
    return MultiTaskEngine(datas, parts, w0s, cfg)
