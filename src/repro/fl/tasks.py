"""Per-model FL task registry: the seam that makes the protocol stack
model-agnostic.

The paper's protocols (Algs. 1-2) and wire compression (Algs. 3-4) never
look inside the model: Alg. 1's device process needs only "run E epochs of
prox-SGD on the local objective", Alg. 2's server aggregates opaque weight
pytrees, and Algs. 3-4 compress tensors leaf-by-leaf.  An :class:`FLTask`
captures exactly that contract — everything the engine, the legacy
simulator, and the protocol strategies need to train *some* model family
under *any* protocol:

* ``init_params(key)`` — model init from a PRNG key (Alg. 1 line 1's w^0).
* ``loss(params, batch)`` — the device objective f_k (Eq. 5's loss term);
  ``batch`` uses the historical keys ``{"images": inputs, "labels":
  targets}`` shared with :func:`repro.core.client.local_update` (for the
  LM task, ``"images"`` carries the token matrix).
* ``eval_metric(params, x, y)`` — scalar in [0, 1] (accuracy-like), what
  the simulators log per aggregation round.
* ``cohort_loss(params, x, y)`` — the vectorized multi-device objective:
  every params leaf carries a leading cohort axis C, inputs are
  ``(C, B, ...)``, and the value is the mean over all cohort elements
  (matching ``cnn_cohort_loss``; on a stacked singleton it equals the
  serial ``loss``, which the conformance suite pins).  Each task picks the
  formulation that lowers well: the CNN im2col's its convs into batched
  einsums (``vmap``-of-conv lowers to ~8x-slower grouped convs on CPU —
  the PR-1 lesson), while the transformer/MLP stacks are pure matmuls, so
  ``vmap`` over the cohort axis already lowers to fast batched GEMMs.
* ``make_data(n_train, n_test, seed)`` — synthetic dataset dict with the
  ``{"x_train", "y_train", "x_test", "y_test"}`` keys the simulators and
  partitioners consume.
* ``forward`` / ``features`` — optional logits / penultimate-representation
  functions; MOON's model-contrastive term needs ``features`` (tasks that
  omit it simply can't run the MOON baseline).

``TASKS`` maps ``SimConfig.task`` names to registered instances;
``get_task`` resolves one.  Registering a new model family is one
:class:`FLTask` construction — no protocol, engine, or codec code changes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import make_fmnist_like
from repro.models import mlp
from repro.models import transformer as tfm
from repro.models.cnn import (cnn_accuracy, cnn_cohort_loss, cnn_features,
                              cnn_forward, cnn_loss, init_cnn)

__all__ = ["FLTask", "TASKS", "get_task", "register_task"]


@dataclasses.dataclass(frozen=True)
class FLTask:
    """One model family's FL bundle (see module docstring for the contract).

    Frozen so instances are safely shared and their function attributes are
    stable objects — the simulators pass ``loss`` / ``cohort_loss`` /
    ``eval_metric`` as static jit arguments, so re-resolving a task must
    not retrigger compilation."""

    name: str
    init_params: Callable[[jax.Array], Any]
    loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    eval_metric: Callable[[Any, jax.Array, jax.Array], jax.Array]
    cohort_loss: Callable[[Any, jax.Array, jax.Array], jax.Array]
    make_data: Callable[[int, int, int], Dict[str, np.ndarray]]
    forward: Optional[Callable[[Any, jax.Array], jax.Array]] = None
    features: Optional[Callable[[Any, jax.Array], jax.Array]] = None
    # the transformer-stack ModelConfig behind an LM task, when there is
    # one: the FL->serve bridge (repro.launch.serve --from-sim) needs the
    # config to rebuild the weight treedef and drive prefill/decode_step.
    # None for non-LM families (CNN/MLP) — those are not servable LMs.
    model_cfg: Optional[ModelConfig] = None


TASKS: Dict[str, FLTask] = {}


def register_task(task: FLTask) -> FLTask:
    if task.name in TASKS:
        raise ValueError(f"task {task.name!r} already registered")
    TASKS[task.name] = task
    return task


def get_task(name: str) -> FLTask:
    try:
        return TASKS[name]
    except KeyError:
        raise ValueError(f"unknown task {name!r}; "
                         f"expected one of {sorted(TASKS)}") from None


# ----------------------------------------------------------------------
# fmnist_cnn — the paper's own workload (§5.1), moved behind the seam.
# The function objects are the very same ones the pre-registry simulators
# imported, so the default path's jit caches and numerics are untouched.
# ----------------------------------------------------------------------
register_task(FLTask(
    name="fmnist_cnn",
    init_params=init_cnn,
    loss=cnn_loss,
    eval_metric=cnn_accuracy,
    cohort_loss=cnn_cohort_loss,
    make_data=lambda n_train, n_test, seed: make_fmnist_like(
        n_train, n_test, seed=seed),
    forward=cnn_forward,
    features=cnn_features,
))


# ----------------------------------------------------------------------
# fmnist_mlp — one-hidden-layer MLP (repro.models.mlp) on the same
# synthetic FMNIST images.  Deliberately minimal: the smallest non-CNN
# family, cheap enough for the conformance suite's end-to-end runs on this
# ~4 ms/dispatch CPU.
# ----------------------------------------------------------------------
register_task(FLTask(
    name="fmnist_mlp",
    init_params=mlp.init_mlp,
    loss=mlp.mlp_loss,
    eval_metric=mlp.mlp_accuracy,
    cohort_loss=mlp.mlp_cohort_loss,
    make_data=lambda n_train, n_test, seed: make_fmnist_like(
        n_train, n_test, seed=seed),
    forward=mlp.mlp_forward,
    features=mlp.mlp_features,
))


# ----------------------------------------------------------------------
# transformer_lm — a tiny decoder-only LM (repro.models.transformer stack)
# on a synthetic copy-structured token stream.  Demonstrates that the
# whole protocol/codec stack is model-shape-agnostic: inputs are int32
# token matrices, the loss is next-token CE, and the "accuracy" logged per
# round is next-token top-1.
# ----------------------------------------------------------------------
LM_SEQ_LEN = 16

_LM_CFG = ModelConfig(
    name="fl-transformer-lm", family="dense",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
    tie_embeddings=True)


def init_lm(key) -> Dict[str, Any]:
    return tfm.init_model(key, _LM_CFG)


def lm_forward(params, tokens: jax.Array) -> jax.Array:
    logits, _ = tfm.forward(params, {"tokens": tokens}, _LM_CFG)
    return logits


def lm_task_loss(params, batch) -> jax.Array:
    """Next-token CE; ``batch["images"]`` carries the (B, S) int32 tokens
    (the historical batch key — see the module docstring)."""
    loss, _ = tfm.lm_loss(params, {"tokens": batch["images"]}, _LM_CFG)
    return loss


def lm_accuracy(params, tokens, labels) -> jax.Array:
    """Next-token top-1 over the sequence (``labels`` is a placeholder —
    LM targets are the shifted tokens themselves)."""
    del labels
    logits = lm_forward(params, tokens)
    return (logits[:, :-1].argmax(-1) == tokens[:, 1:]).mean()


def lm_cohort_loss(params, tokens: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-device-weights LM: leaves (C, ...), tokens (C, B, S).  The stack
    is matmuls end-to-end, so vmap over the cohort axis lowers straight to
    batched GEMMs (no grouped-conv trap here)."""
    del labels
    per_device = jax.vmap(
        lambda p, t: tfm.lm_loss(p, {"tokens": t}, _LM_CFG)[0])(params, tokens)
    return per_device.mean()


def make_lm_data(n_train: int, n_test: int, seed: int = 0,
                 seq: int = LM_SEQ_LEN) -> Dict[str, np.ndarray]:
    """Copy-structured token stream (second half = first half shifted by 1)
    so next-token loss genuinely decreases.  ``y_*`` are 10-way pseudo-labels
    bucketed from the leading token: the LM objective ignores them, but the
    label-skew partitioners (paper non-IID split) need real classes to skew
    device data by — here, by a sequence's opening token range."""
    vocab = _LM_CFG.vocab

    def gen(n, rs):
        toks = rs.randint(0, vocab, size=(n, seq)).astype(np.int32)
        half = seq // 2
        toks[:, half:half * 2] = (toks[:, :half] + 1) % vocab
        return toks, (toks[:, 0] * 10 // vocab).astype(np.int32)

    xtr, ytr = gen(n_train, np.random.RandomState(seed))
    xte, yte = gen(n_test, np.random.RandomState(seed + 1))
    return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte}


register_task(FLTask(
    name="transformer_lm",
    init_params=init_lm,
    loss=lm_task_loss,
    eval_metric=lm_accuracy,
    cohort_loss=lm_cohort_loss,
    make_data=make_lm_data,
    forward=lm_forward,
    features=None,            # no contrastive head: MOON is CNN/MLP-only
    model_cfg=_LM_CFG,
))


# ----------------------------------------------------------------------
# moe_lm / ssm_lm — the Mixture-of-Experts and SSM-only (Mamba2/SSD)
# families of the transformer stack, registered so a multi-task fleet
# (repro.fl.fleet) is genuinely heterogeneous: the same next-token
# objective and copy-structured token stream as transformer_lm, but the
# layer bodies route through repro/models/moe.py (Switch-style top-k
# routing + load-balance aux loss; the dense reference path on CPU) and
# repro/models/ssm.py (chunked SSD scan — ``ssm_chunk`` must divide
# LM_SEQ_LEN).  One FLTask construction each: no protocol, codec, or
# engine code knows these families exist.
# ----------------------------------------------------------------------
def _lm_family_fns(cfg: ModelConfig):
    """The transformer_lm task functions, closed over an arbitrary
    ``ModelConfig`` — each family gets its own stable function objects
    (FLTask attributes are static jit args, so sharing would be fine, but
    distinct objects keep per-task jit caches independent)."""

    def init_params(key):
        return tfm.init_model(key, cfg)

    def forward(params, tokens):
        logits, _ = tfm.forward(params, {"tokens": tokens}, cfg)
        return logits

    def loss(params, batch):
        l, _ = tfm.lm_loss(params, {"tokens": batch["images"]}, cfg)
        return l

    def eval_metric(params, tokens, labels):
        del labels
        logits = forward(params, tokens)
        return (logits[:, :-1].argmax(-1) == tokens[:, 1:]).mean()

    def cohort_loss(params, tokens, labels):
        del labels
        per_device = jax.vmap(
            lambda p, t: tfm.lm_loss(p, {"tokens": t}, cfg)[0])(params,
                                                               tokens)
        return per_device.mean()

    return init_params, loss, eval_metric, cohort_loss, forward


_MOE_LM_CFG = ModelConfig(
    name="fl-moe-lm", family="moe",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
    tie_embeddings=True, n_experts=4, moe_top_k=2)

_SSM_LM_CFG = ModelConfig(
    name="fl-ssm-lm", family="ssm",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
    tie_embeddings=True, ssm_state=8, ssm_head_dim=16, ssm_expand=2,
    ssm_conv_width=4, ssm_chunk=8)   # chunk 8 divides LM_SEQ_LEN=16

assert _MOE_LM_CFG.is_moe and _SSM_LM_CFG.is_ssm_only
assert LM_SEQ_LEN % _SSM_LM_CFG.ssm_chunk == 0

(_moe_init, _moe_loss, _moe_acc, _moe_cohort, _moe_fwd) = \
    _lm_family_fns(_MOE_LM_CFG)
(_ssm_init, _ssm_loss, _ssm_acc, _ssm_cohort, _ssm_fwd) = \
    _lm_family_fns(_SSM_LM_CFG)

register_task(FLTask(
    name="moe_lm",
    init_params=_moe_init,
    loss=_moe_loss,
    eval_metric=_moe_acc,
    cohort_loss=_moe_cohort,
    make_data=make_lm_data,
    forward=_moe_fwd,
    features=None,
    model_cfg=_MOE_LM_CFG,
))

register_task(FLTask(
    name="ssm_lm",
    init_params=_ssm_init,
    loss=_ssm_loss,
    eval_metric=_ssm_acc,
    cohort_loss=_ssm_cohort,
    make_data=make_lm_data,
    forward=_ssm_fwd,
    features=None,
    model_cfg=_SSM_LM_CFG,
))
