from repro.core.codecs import (CODECS, Codec, DenseRefCodec, IdentityCodec,
                               PackedBitstreamCodec, ThresholdGraphCodec,
                               resolve_codec)
from repro.fl.engine import (ChannelMeter, CohortTrainer, DeviceRegistry,
                             FLEngine, SerialTrainer)
from repro.fl.policies import (POLICIES, CodecPolicy, DispatchContext,
                               make_policy)
from repro.fl.protocols import (METHODS, STRATEGIES, ProtocolStrategy,
                                best_acc_within, make_setup, make_sim,
                                make_strategy, profile_compression,
                                run_method, time_to_acc)
from repro.fl.simulator import (FLSimulator, LogEntry, ScenarioConfig,
                                SimConfig, TierSpec)
from repro.fl.tasks import TASKS, FLTask, get_task, register_task

__all__ = [
    # codec API re-export: FL code selects wire formats through this seam
    "CODECS", "Codec", "DenseRefCodec", "IdentityCodec",
    "PackedBitstreamCodec", "ThresholdGraphCodec", "resolve_codec",
    "ChannelMeter", "CohortTrainer", "DeviceRegistry", "FLEngine",
    "SerialTrainer",
    # per-device adaptive codec policies (SimConfig.codec_policy)
    "POLICIES", "CodecPolicy", "DispatchContext", "make_policy",
    "METHODS", "STRATEGIES", "ProtocolStrategy", "best_acc_within",
    "make_setup", "make_sim", "make_strategy", "profile_compression",
    "run_method", "time_to_acc",
    "FLSimulator", "LogEntry", "ScenarioConfig", "SimConfig", "TierSpec",
    # task registry: per-model-family FL bundles (SimConfig.task)
    "TASKS", "FLTask", "get_task", "register_task",
]
