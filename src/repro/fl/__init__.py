from repro.core.codecs import (CODECS, Codec, DenseRefCodec, IdentityCodec,
                               PackedBitstreamCodec, ThresholdGraphCodec,
                               resolve_codec)
from repro.fl.engine import (ChannelMeter, CohortTrainer, DeviceRegistry,
                             FLEngine, SerialTrainer)
from repro.fl.protocols import (METHODS, STRATEGIES, ProtocolStrategy,
                                best_acc_within, make_setup, make_sim,
                                make_strategy, profile_compression,
                                run_method, time_to_acc)
from repro.fl.simulator import (FLSimulator, LogEntry, ScenarioConfig,
                                SimConfig, TierSpec)

__all__ = [
    # codec API re-export: FL code selects wire formats through this seam
    "CODECS", "Codec", "DenseRefCodec", "IdentityCodec",
    "PackedBitstreamCodec", "ThresholdGraphCodec", "resolve_codec",
    "ChannelMeter", "CohortTrainer", "DeviceRegistry", "FLEngine",
    "SerialTrainer",
    "METHODS", "STRATEGIES", "ProtocolStrategy", "best_acc_within",
    "make_setup", "make_sim", "make_strategy", "profile_compression",
    "run_method", "time_to_acc",
    "FLSimulator", "LogEntry", "ScenarioConfig", "SimConfig", "TierSpec",
]


def __getattr__(name):
    # One-release deprecation shim: FL code used to reach for the raw
    # ``roundtrip_pytree`` channel; the codec seam replaced it (use
    # ``resolve_codec("dense", p_s, p_q).roundtrip(tree, rng=rng)``).
    if name == "roundtrip_pytree":
        import warnings
        warnings.warn(
            "importing roundtrip_pytree from repro.fl is deprecated and will "
            "be removed next release; use repro.core.codecs.DenseRefCodec "
            "(or resolve_codec) instead", DeprecationWarning, stacklevel=2)
        from repro.core.compression import roundtrip_pytree
        return roundtrip_pytree
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
