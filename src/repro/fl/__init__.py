from repro.fl.engine import (ChannelMeter, CohortTrainer, DeviceRegistry,
                             FLEngine, SerialTrainer)
from repro.fl.protocols import (METHODS, STRATEGIES, ProtocolStrategy,
                                best_acc_within, make_setup, make_sim,
                                make_strategy, profile_compression,
                                run_method, time_to_acc)
from repro.fl.simulator import (FLSimulator, LogEntry, ScenarioConfig,
                                SimConfig, TierSpec)
