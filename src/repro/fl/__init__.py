from repro.fl.protocols import (best_acc_within, make_setup,
                                profile_compression, run_method, time_to_acc)
from repro.fl.simulator import FLSimulator, LogEntry, SimConfig
