from repro.data.synthetic import (make_fmnist_like, make_token_batch,
                                  partition_dirichlet, partition_iid,
                                  partition_noniid_classes)
