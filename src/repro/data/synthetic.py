"""Synthetic data pipeline.

Fashion-MNIST is not downloadable in this offline container, so
``make_fmnist_like`` builds a 10-class 28x28 grayscale dataset from smoothed
class prototypes + structured noise.  Classes are genuinely separable but not
trivially so (prototype mixtures + per-sample deformation), which preserves
the *relative* comparisons the paper makes (method A vs B on identical data).

Partitioners reproduce the paper's device splits:
  - ``partition_iid``: uniform random split across N devices.
  - ``partition_noniid_classes``: each device samples from a random subset of
    ``classes_per_device`` classes (paper: 2 of 10).
  - ``partition_dirichlet``: Dir(alpha) label skew (extra, for ablations).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def _smooth(img: np.ndarray, iters: int = 2) -> np.ndarray:
    for _ in range(iters):
        img = (img + np.roll(img, 1, 0) + np.roll(img, -1, 0)
               + np.roll(img, 1, 1) + np.roll(img, -1, 1)) / 5.0
    return img


def make_fmnist_like(n_train: int = 60000, n_test: int = 10000,
                     n_classes: int = 10, seed: int = 0,
                     noise: float = 0.5) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(seed)
    # weak class signal on a shared background: per-pixel SNR << 1 so the
    # CNN needs many SGD steps (like real FMNIST), instead of one round
    shared = _smooth(rng.randn(28, 28), 3)
    protos = []
    for c in range(n_classes):
        base = shared + 0.45 * _smooth(rng.randn(28, 28), 3)
        mode2 = base + 0.3 * _smooth(rng.randn(28, 28), 2)
        protos.append((base, mode2))

    def gen(n, rs):
        labels = rs.randint(0, n_classes, size=n).astype(np.int32)
        imgs = np.empty((n, 28, 28, 1), np.float32)
        modes = rs.randint(0, 2, size=n)
        shifts = rs.randint(-3, 4, size=(n, 2))
        eps = rs.randn(n, 28, 28).astype(np.float32) * noise
        for i in range(n):
            p = protos[labels[i]][modes[i]]
            p = np.roll(p, shifts[i, 0], 0)
            p = np.roll(p, shifts[i, 1], 1)
            imgs[i, :, :, 0] = p + eps[i]
        return imgs, labels

    xtr, ytr = gen(n_train, rng)
    xte, yte = gen(n_test, np.random.RandomState(seed + 1))
    return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte}


# -- device partitioners -------------------------------------------------
def partition_iid(n_samples: int, n_devices: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(idx, n_devices)]


def partition_noniid_classes(labels: np.ndarray, n_devices: int,
                             classes_per_device: int = 2,
                             seed: int = 0) -> List[np.ndarray]:
    """Paper's non-IID split: each device draws from a random subset of
    ``classes_per_device`` classes."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    per_dev = len(labels) // n_devices
    out = []
    for _ in range(n_devices):
        cls = rng.choice(n_classes, classes_per_device, replace=False)
        pool = np.concatenate([by_class[c] for c in cls])
        out.append(np.sort(rng.choice(pool, per_dev, replace=False)))
    return out


def partition_dirichlet(labels: np.ndarray, n_devices: int, alpha: float = 0.5,
                        seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    out: List[List[int]] = [[] for _ in range(n_devices)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_devices)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for d, part in enumerate(np.split(idx, cuts)):
            out[d].extend(part.tolist())
    return [np.sort(np.array(d, np.int64)) for d in out]


# -- LM token stream (for transformer examples / smoke) -------------------
def make_token_batch(rng: np.random.RandomState, batch: int, seq: int,
                     vocab: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream (so loss can actually decrease)."""
    base = rng.randint(0, vocab, size=(batch, seq), dtype=np.int64)
    # inject copy structure: second half repeats first half shifted
    half = seq // 2
    base[:, half:half * 2] = (base[:, :half] + 1) % vocab
    return {"tokens": base.astype(np.int32)}
