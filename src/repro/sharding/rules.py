"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate activations with *logical* axis names; a ``Rules`` object maps
them to mesh axes.  Outside a mesh context (CPU smoke tests) every helper is a
no-op, so the same model code runs unsharded.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axis (single-pod default). ``batch`` picks up the extra
# ``pod`` axis on the multi-pod mesh.
SINGLE_POD_MAPPING = {
    "batch": "data",
    "fed_group": "data",          # federated groups live on the data axis
    "seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "conv": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "classes": None,
    "stack": None,                # stacked-layer leading axis from scan
}

MULTI_POD_OVERRIDES = {
    "batch": ("pod", "data"),
    "fed_group": ("pod", "data"),
}


class Rules:
    def __init__(self, mesh: Mesh, mapping: Optional[dict] = None):
        self.mesh = mesh
        m = dict(SINGLE_POD_MAPPING)
        if "pod" in mesh.axis_names:
            m.update(MULTI_POD_OVERRIDES)
        if mapping:
            m.update(mapping)
        self.mapping = m

    def with_overrides(self, **overrides) -> "Rules":
        """New Rules with some logical axes remapped (e.g. inside the fed
        group-local region, ``batch``/``seq`` must NOT claim the fed axes)."""
        m = dict(self.mapping)
        m.update(overrides)
        r = Rules.__new__(Rules)
        r.mesh = self.mesh
        r.mapping = m
        return r

    # -- spec construction -------------------------------------------------
    def _mesh_size(self, axis: AxisVal) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return math.prod(self.mesh.shape[a] for a in axis)
        return self.mesh.shape[axis]

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical axes; drops mesh axes that don't divide."""
        parts = []
        for i, name in enumerate(logical):
            ax = self.mapping.get(name) if name else None
            if ax is not None and shape is not None:
                if shape[i] % self._mesh_size(ax) != 0:
                    ax = None  # non-divisible (e.g. smollm 9 heads on 16-way TP)
            parts.append(ax)
        return P(*parts)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


_local = threading.local()


def active_rules() -> Optional[Rules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if rules are active; identity otherwise."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(logical, getattr(x, "shape", None)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    jax >= 0.5 exposes it as ``jax.shard_map(..., check_vma=...)``; on the
    0.4.x line only ``jax.experimental.shard_map`` exists and the replication
    check flag is spelled ``check_rep``.  All repo call sites go through this
    wrapper so the model code stays version-agnostic."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


# ----------------------------------------------------------------------
# name-based parameter sharding: leaf path keywords -> logical axes per ndim.
# Parameters created by repro.models use these canonical names.
_PARAM_LOGICAL = {
    "embed": ("vocab", "d_model"),
    "lm_head": ("d_model", "vocab"),
    "patch_proj": ("d_model", "d_model"),
    "wq": ("d_model", "heads"),
    "wk": ("d_model", "kv_heads"),
    "wv": ("d_model", "kv_heads"),
    "wo": ("heads", "d_model"),
    "w_gate": ("d_model", "ffn"),
    "w_up": ("d_model", "ffn"),
    "w_down": ("ffn", "d_model"),
    "router": ("d_model", None),
    # expert weights shard on the expert axis only (EP); ffn dim stays local
    "e_gate": ("experts", None, None),
    "e_up": ("experts", None, None),
    "e_down": ("experts", None, None),
    "in_proj": ("d_model", None),
    "out_proj": (None, "d_model"),
    "conv_w": ("conv", None),
    "a_log": (None,),
    "ssm_d": (None,),
    "dt_bias": (None,),
    # cnn / misc
    "conv1": (None, None, None, None),
    "conv2": (None, None, None, None),
    "fc1": (None, "ffn"),
    "fc2": ("ffn", None),
}


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes of a parameter given its (dot-joined) tree path."""
    leaf = path.split("/")[-1]
    base = _PARAM_LOGICAL.get(leaf)
    if base is None:
        return (None,) * ndim
    if len(base) == ndim:
        return base
    if len(base) < ndim:
        # stacked by scan over layers / hybrid groups / within-group index:
        # any number of leading 'stack' axes (jamba has two)
        return ("stack",) * (ndim - len(base)) + tuple(base)
    return (None,) * ndim


def param_shardings(rules: Rules, params):
    """NamedSharding pytree for a parameter pytree (by leaf path names)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
        logical = logical_axes_for("/".join(keys), leaf.ndim)
        out.append(rules.sharding(logical, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)
