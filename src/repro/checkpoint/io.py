"""Minimal msgpack pytree checkpointing (no orbax in this container)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        return {b"__nd__": True, b"dtype": arr.dtype.str,
                b"shape": list(arr.shape), b"data": arr.tobytes()}
    return obj


def _decode(obj):
    if isinstance(obj, dict) and obj.get(b"__nd__"):
        return np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"])
                             ).reshape(obj[b"shape"]).copy()
    return obj


def save_pytree(path: str, tree: Any) -> None:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_encode(np.asarray(l)) for l in flat],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode, use_bin_type=True))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode, raw=True)
    leaves = [_decode(l) for l in payload[b"leaves"]]
    flat, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat) == len(leaves), "checkpoint/pytree structure mismatch"
    restored = [jnp.asarray(l).astype(f.dtype).reshape(f.shape)
                for l, f in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, restored)
