"""Minimal msgpack pytree checkpointing (no orbax in this container)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        return {b"__nd__": True, b"dtype": arr.dtype.str,
                b"shape": list(arr.shape), b"data": arr.tobytes()}
    return obj


def _decode(obj):
    if isinstance(obj, dict) and obj.get(b"__nd__"):
        return np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"])
                             ).reshape(obj[b"shape"]).copy()
    return obj


def save_pytree(path: str, tree: Any) -> None:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_encode(np.asarray(l)) for l in flat],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode, use_bin_type=True))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like``, validating the stored
    treedef, per-leaf dtypes and shapes against it — a checkpoint written
    from a different model structure fails loudly instead of silently
    coercing leaves by position."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode, raw=True)
    leaves = [_decode(l) for l in payload[b"leaves"]]
    flat, treedef = jax.tree_util.tree_flatten(like)
    stored_treedef = payload[b"treedef"].decode()
    if stored_treedef != str(treedef):
        raise ValueError(
            f"checkpoint treedef mismatch at {path!r}:\n"
            f"  stored:   {stored_treedef}\n  expected: {str(treedef)}")
    if len(flat) != len(leaves):
        raise ValueError(
            f"checkpoint at {path!r} holds {len(leaves)} leaves, "
            f"`like` has {len(flat)}")
    restored = []
    for i, (l, f) in enumerate(zip(leaves, flat)):
        l = np.asarray(l)
        want = np.asarray(f)
        if l.dtype != want.dtype:
            raise ValueError(
                f"checkpoint leaf {i} dtype mismatch at {path!r}: "
                f"stored {l.dtype}, expected {want.dtype}")
        if l.shape != want.shape:
            raise ValueError(
                f"checkpoint leaf {i} shape mismatch at {path!r}: "
                f"stored {l.shape}, expected {want.shape}")
        restored.append(jnp.asarray(l))
    return jax.tree_util.tree_unflatten(treedef, restored)


# ----------------------------------------------------------------------
# Generic state blobs (simulator checkpoint/resume)
# ----------------------------------------------------------------------
# ``FLEngine.state_dict()`` / ``MultiTaskEngine.state_dict()`` produce plain
# nested dicts/lists of scalars, strings and numpy arrays; these two
# round-trip such a structure through one msgpack file (arrays via the same
# ndarray extension hook as the pytree format above).

def save_blob(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(obj, default=_encode, use_bin_type=True))


def load_blob(path: str) -> Any:
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read(), object_hook=_decode, raw=False,
                               strict_map_key=False)


def load_sim_params(path: str, like: Any, task: int = 0) -> Any:
    """Global model weights out of a simulator checkpoint blob.

    Accepts either an ``FLEngine.state_dict()`` blob (server weights under
    ``core.server.w``) or a ``MultiTaskEngine.state_dict()`` blob (job
    ``task``'s weights under ``tasks[task].server.w``).  The blobs store
    the weight pytree as a flat leaf list in tree order, so ``like`` (a
    pytree with the training-time structure, e.g. the task's
    ``init_params`` output) supplies the treedef; per-leaf dtypes and
    shapes are validated against it like :func:`load_pytree`."""
    blob = load_blob(path)
    if "core" in blob:                      # FLEngine.state_dict
        leaves = blob["core"]["server"]["w"]
    elif "tasks" in blob:                   # MultiTaskEngine.state_dict
        jobs = blob["tasks"]
        if not 0 <= task < len(jobs):
            raise ValueError(f"fleet checkpoint at {path!r} holds "
                             f"{len(jobs)} tasks; task index {task} is out "
                             "of range")
        leaves = jobs[task]["server"]["w"]
    else:
        raise ValueError(f"{path!r} is not an engine or fleet checkpoint "
                         "blob (no 'core' or 'tasks' key)")
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != len(leaves):
        raise ValueError(f"checkpoint at {path!r} holds {len(leaves)} "
                         f"weight leaves, `like` has {len(flat)}")
    restored = []
    for i, (l, f) in enumerate(zip(leaves, flat)):
        l, want = np.asarray(l), np.asarray(f)
        if l.dtype != want.dtype:
            raise ValueError(f"weight leaf {i} dtype mismatch at {path!r}: "
                             f"stored {l.dtype}, expected {want.dtype}")
        if l.shape != want.shape:
            raise ValueError(f"weight leaf {i} shape mismatch at {path!r}: "
                             f"stored {l.shape}, expected {want.shape}")
        restored.append(jnp.asarray(l))
    return jax.tree_util.tree_unflatten(treedef, restored)
