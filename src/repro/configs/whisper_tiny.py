"""Whisper-tiny [arXiv:2212.04356] — enc-dec; mel+conv frontend stubbed.

``input_specs`` provides precomputed audio frame embeddings (1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=4,              # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    is_encoder_decoder=True,
    enc_seq=1500,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny/smoke", family="audio",
        n_layers=2, n_enc_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=256, vocab=512, is_encoder_decoder=True, enc_seq=64,
    )
