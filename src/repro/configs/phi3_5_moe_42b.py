"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    moe_top_k=2,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b/smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab=512, n_experts=4, moe_top_k=2,
    )
