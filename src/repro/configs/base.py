"""Architecture config system.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact assigned full-scale config) and ``smoke()`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts) used by the
CPU smoke tests.  Full configs are only ever lowered via ShapeDtypeStructs in
the dry-run; they are never materialized.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation for the config numbers

    # transformer trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    qk_norm: bool = False
    tie_embeddings: bool = False
    gated_mlp: bool = True           # SwiGLU; False -> 2-matrix GELU (granite)

    # MoE
    n_experts: int = 0               # 0 => dense FFN
    moe_top_k: int = 0
    moe_every: int = 1               # MoE FFN every k-th layer (1 = all layers)
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0               # N; 0 => no SSM layers
    ssm_head_dim: int = 64           # P
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (jamba): attention layer every `attn_every` layers; others SSM
    attn_every: int = 0              # 0 => not hybrid

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500              # audio frame positions (stubbed frontend)

    # VLM
    n_patches: int = 0               # image patch embeddings prepended (stubbed frontend)

    # attention variant for long-context decode
    sliding_window: int = 8192

    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.ssm_state > 0 and self.attn_every == 0 and not self.is_encoder_decoder

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_every > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D roofline term)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd = self.head_dim

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def dense_ffn() -> int:
            return (3 if self.gated_mlp else 2) * d * f

        def moe_ffn() -> int:
            return self.n_experts * 3 * d * f + d * self.n_experts  # experts + router

        def ssm_params() -> int:
            di, n = self.d_inner, self.ssm_state
            in_proj = d * (2 * di + 2 * n + self.ssm_heads)  # x, z, B, C, dt
            conv = self.ssm_conv_width * (di + 2 * n)
            out = di * d
            return in_proj + conv + out + 2 * self.ssm_heads  # + A, D per head

        if self.is_encoder_decoder:
            for _ in range(self.n_enc_layers):
                total += attn_params() + dense_ffn() + 2 * d
            for _ in range(self.n_layers):
                total += 2 * attn_params() + dense_ffn() + 3 * d  # self + cross
            return total

        for i in range(self.n_layers):
            if self.is_hybrid:
                is_attn = (i % self.attn_every) == (self.attn_every - 1)
                total += attn_params() if is_attn else ssm_params()
            elif self.is_ssm_only:
                total += ssm_params()
            else:
                total += attn_params()
            if self.ssm_state == 0 or self.is_hybrid:
                use_moe = self.is_moe and (i % self.moe_every == self.moe_every - 1)
                total += moe_ffn() if use_moe else dense_ffn()
            total += 2 * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if (self.ssm_state == 0 or self.is_hybrid)
            and (i % self.moe_every == self.moe_every - 1)
        )
        inactive = n_moe_layers * (self.n_experts - self.moe_top_k) * 3 * self.d_model * self.d_ff
        return full - inactive


# ----------------------------------------------------------------------
ARCH_IDS: Tuple[str, ...] = (
    "phi3_5_moe_42b",
    "jamba_v0_1_52b",
    "smollm_135m",
    "internvl2_2b",
    "whisper_tiny",
    "mamba2_370m",
    "llama4_scout_17b",
    "moonshot_v1_16b",
    "granite_34b",
    "qwen3_1_7b",
    "fmnist_cnn",          # the paper's own model (FL workhorse, not a transformer)
)

# CLI-friendly aliases matching the assignment sheet.
ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "smollm-135m": "smollm_135m",
    "internvl2-2b": "internvl2_2b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "granite-34b": "granite_34b",
    "qwen3-1.7b": "qwen3_1_7b",
    "fmnist-cnn": "fmnist_cnn",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke()


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
