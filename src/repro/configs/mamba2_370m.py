"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1024,
    n_heads=16,              # unused (attention-free) but kept for head_dim math
    n_kv_heads=16,
    d_ff=0,                  # no MLP: mamba blocks only
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m/smoke", family="ssm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=0, vocab=512,
        ssm_state=32, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
        tie_embeddings=True,
    )
