"""Granite-34B-Code [arXiv:2405.04324] — deep llama-arch, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,            # multi-query attention
    d_ff=24576,
    vocab=49152,
    gated_mlp=False,        # GPT-BigCode-style 2-matrix GELU MLP (-> 34B)
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-34b/smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512, vocab=512,
        gated_mlp=False,
    )
