"""Jamba v0.1 52B [arXiv:2403.19887]: Mamba+attention 1:7 interleave, MoE 16e top-2.

Real Jamba: blocks of 8 layers with one attention layer (ratio 1:7) and MoE FFN
every other layer (e=16, top-2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    moe_top_k=2,
    moe_every=2,
    attn_every=8,                # 1 attention layer per 8 (1:7 mamba:attn)
    ssm_state=16,                # Jamba-1 uses Mamba-1 d_state=16; SSD path with N=16
    ssm_head_dim=64,
    ssm_expand=2,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b/smoke", family="hybrid",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
        n_experts=4, moe_top_k=2, moe_every=2,
        attn_every=2, ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    )
