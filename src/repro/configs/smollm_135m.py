"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m/smoke", family="dense",
        n_layers=2, d_model=144, n_heads=3, n_kv_heads=1, d_ff=384, vocab=512,
        tie_embeddings=True,
    )
