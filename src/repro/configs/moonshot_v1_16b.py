"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — fine-grained MoE 64e top-6."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # per-expert ffn (DeepSeek-V3-style fine-grained experts)
    vocab=163840,
    n_experts=64,
    moe_top_k=6,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b/smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        n_experts=4, moe_top_k=2,
    )
