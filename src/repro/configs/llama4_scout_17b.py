"""Llama-4 Scout 17B-A 16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE top-1."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    moe_top_k=1,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e/smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
        n_experts=4, moe_top_k=1,
    )
