"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — GQA + qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b/smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
        qk_norm=True, tie_embeddings=True,
    )
