"""InternVL2-2B [arXiv:2404.16821] — InternViT frontend (stubbed) + InternLM2 LM.

The vision encoder is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings (n_patches, d_model); this config is the LM
backbone that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    n_patches=256,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b/smoke", family="vlm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
        n_patches=16,
    )
