"""The paper's own model: 2-conv CNN for Fashion-MNIST (TEASQ-Fed §5.1).

Not part of the assigned transformer pool; this is the federated-learning
workhorse used by the protocol simulator and the paper-table benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="fmnist-cnn",
    family="cnn",
    source="TEASQ-Fed §5.1 (Fashion-MNIST CNN)",
    n_layers=2,              # two conv layers
    d_model=32,              # conv channels
    n_heads=1, n_kv_heads=1,
    d_ff=128,                # fully-connected width
    vocab=10,                # classes
)


def smoke() -> ModelConfig:
    return CONFIG  # already tiny
