"""Pluggable wire codecs: one seam for the paper's compression (Algs. 3-4).

The paper's headline contribution is wire compression for asynchronous FL:

* **Algorithm 3 (compress)** — keep the top ``p_s`` fraction of each tensor
  by magnitude (``k = max(1, round(p_s * n))`` values), quantize the kept
  values to ``p_q`` bits with a QSGD-style symmetric uniform quantizer
  (levels in ``[-L, L]``, ``L = 2**(p_q-1) - 1``, one f32 max-abs scale per
  tensor), and transmit ``(scale, values, indices)`` — zeros are not sent.
* **Algorithm 4 (decompress)** — dequantize ``level * scale / L`` and
  scatter the values back to their indices in a zero tensor.
* **Wire size** (the analytic price): per tensor
  ``bits = k * (min(p_q, 32) + [k < n] * ceil(log2 n)) + 32``, and a pytree
  travels as ONE bit-level concatenated stream of ``ceil(sum_bits / 8)``
  bytes (``repro.core.compression.expected_pytree_wire_bytes``).

Every consumer — ``FLEngine``, the legacy ``FLSimulator``, the Alg. 5
profiler, benchmarks — goes through the :class:`Codec` interface instead of
hand-picking one of the underlying implementations:

* :class:`IdentityCodec` — no compression; prices the dense f32 payload.
  ``resolve_codec`` returns it at the uncompressed point ``(p_s >= 1,
  p_q >= 32)`` for every family (the simulator's historical fast path).
* :class:`DenseRefCodec` — the faithful reference codec (Algs. 3-4 exactly,
  optional stochastic QSGD rounding): payload is the per-tensor
  ``{values, indices, scale}`` dict of ``compress_pytree``; byte accounting
  is the packed-stream price.  This is the protocol simulators' default.
* :class:`ThresholdGraphCodec` — the jit/SPMD-safe in-graph channel used by
  the vectorized cohort trainer: binary-search threshold sparsification
  (approximate Top-K, kept fraction within ~2**-iters of ``p_s``) +
  deterministic quantization, applied as a dense masked round trip inside
  the compiled program.  Bytes are priced shape-only.
* :class:`PackedBitstreamCodec` — the REAL wire format: values bit-packed at
  ``p_q`` bits plus delta-coded sorted indices at ``ceil(log2 n)`` bits,
  serialized by the ``repro.kernels.bitpack`` kernels into a single byte
  string whose ``len()`` equals the analytic price *exactly*.  Encode
  selection/quantization is shared with :class:`DenseRefCodec` (same mask,
  same levels, same scale — and the same RNG draw order under stochastic
  rounding), so the two codecs decode to bit-identical trees.  Subsumes the
  orphaned block-local Pallas kernel ``repro.kernels.topk_quant`` as the
  FL stack's packed path.

Protocols pick a codec family by name via ``SimConfig.codec`` and the
``ProtocolStrategy.channel_for(t, device_id=None)`` seam; ``CODECS`` is the registry (new
codec = one subclass + one entry), ``resolve_codec`` binds a family name to
the round's ``(p_s, p_q)`` operating point — per device when an adaptive
policy (``repro.fl.policies``) is active.

The normative bit-layout spec of the packed stream — field order,
offset-binary values, delta-coded indices, and how ``len(bytes)`` ties to
``expected_pytree_wire_bytes`` — is **docs/WIRE_FORMAT.md**.
"""
from __future__ import annotations

import abc
import dataclasses
import functools
from typing import Any, ClassVar, Dict, List, Optional, Tuple, Type

import jax
import numpy as np

from repro.core.compression import (FLOAT_BITS, compress_pytree,
                                    compress_tensor, decompress_pytree,
                                    decompress_tensor,
                                    expected_pytree_wire_bytes,
                                    expected_tensor_wire_bits, index_bits,
                                    pytree_dense_bytes, pytree_wire_bytes,
                                    sparsify_quantize_threshold, topk_count)
from repro.kernels.bitpack import BitReader, pack_segments


@dataclasses.dataclass
class Wire:
    """One encoded transmission.

    ``payload`` is codec-specific (a pytree, a compressed-dict tree, or raw
    ``bytes`` for the packed codec); ``nbytes`` is the metered wire size.
    ``meta`` carries receiver-known framing (treedef / leaf shapes) that is
    protocol-static and therefore not billed to the channel.
    """
    codec: str
    payload: Any
    nbytes: int
    meta: Any = None


class Codec(abc.ABC):
    """encode/decode/price interface every wire implementation satisfies.

    ``p_s``/``p_q`` expose the operating point (1.0/32 = uncompressed) so
    engines can group work by compression parameters (the cohort trainer
    jit-specializes on them).
    """

    name: ClassVar[str] = ""
    p_s: float = 1.0
    p_q: int = FLOAT_BITS

    @abc.abstractmethod
    def encode(self, tree: Any, *,
               rng: Optional[np.random.RandomState] = None) -> Wire:
        """Compress ``tree`` for transmission.  ``rng`` enables stochastic
        (unbiased QSGD) rounding where the codec supports it."""

    @abc.abstractmethod
    def decode(self, wire: Wire) -> Any:
        """Reconstruct the (lossy) tree from a :class:`Wire`."""

    @abc.abstractmethod
    def wire_bytes(self, tree: Any) -> int:
        """Transmitted size for ``tree`` — shape-only (value-independent for
        every registered codec), so schedulers can price a transfer before
        training has produced the update."""

    def roundtrip(self, tree: Any, *,
                  rng: Optional[np.random.RandomState] = None
                  ) -> Tuple[Any, int]:
        """The lossy channel: encode -> wire bytes -> decode."""
        wire = self.encode(tree, rng=rng)
        return self.decode(wire), wire.nbytes


@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """No compression: dense f32 on the wire (TEA-Fed / FedAvg / FedAsync)."""

    name: ClassVar[str] = "identity"

    def encode(self, tree, *, rng=None) -> Wire:
        return Wire(self.name, tree, pytree_dense_bytes(tree))

    def decode(self, wire: Wire):
        return wire.payload

    def wire_bytes(self, tree) -> int:
        return pytree_dense_bytes(tree)


@dataclasses.dataclass(frozen=True)
class DenseRefCodec(Codec):
    """Reference Algs. 3-4 codec over ``compress_pytree``/``decompress_pytree``
    (exact global Top-K, optional stochastic rounding); the payload keeps the
    per-tensor dict layout but is *priced* as the packed bitstream."""

    p_s: float = 1.0
    p_q: int = FLOAT_BITS

    name: ClassVar[str] = "dense"

    def encode(self, tree, *, rng=None) -> Wire:
        ctree = compress_pytree(tree, self.p_s, self.p_q, rng)
        return Wire(self.name, ctree, pytree_wire_bytes(ctree))

    def decode(self, wire: Wire):
        return decompress_pytree(wire.payload)

    def wire_bytes(self, tree) -> int:
        return _packed_price(tree, self.p_s, self.p_q)


@dataclasses.dataclass(frozen=True)
class ThresholdGraphCodec(Codec):
    """jit/SPMD-safe in-graph channel: binary-search threshold sparsification
    + deterministic quantization (``sparsify_quantize_threshold``), the
    operator the vectorized cohort trainer fuses into its scan.  ``encode``
    applies the lossy round trip eagerly; inside a jitted program use
    :meth:`apply` / :meth:`apply_tree` directly."""

    p_s: float = 1.0
    p_q: int = FLOAT_BITS
    iters: int = 12               # threshold binary-search iterations

    name: ClassVar[str] = "threshold"

    def apply(self, x: jax.Array) -> jax.Array:
        """The in-graph lossy operator (traceable, shape-preserving)."""
        return sparsify_quantize_threshold(x, self.p_s, self.p_q, self.iters)

    def apply_tree(self, tree: Any) -> Any:
        return jax.tree.map(self.apply, tree)

    def encode(self, tree, *, rng=None) -> Wire:
        # the eager path is host-dispatch-bound (dozens of small ops per
        # leaf); one jitted program per codec instance fixes that, while
        # in-graph callers (the cohort scan) keep using apply/apply_tree
        return Wire(self.name, _jitted_apply_tree(self)(tree),
                    self.wire_bytes(tree))

    def decode(self, wire: Wire):
        return wire.payload

    def wire_bytes(self, tree) -> int:
        return expected_pytree_wire_bytes(tree, self.p_s, self.p_q)


@functools.lru_cache(maxsize=256)
def _jitted_apply_tree(codec: "ThresholdGraphCodec"):
    return jax.jit(codec.apply_tree)


def _packed_price(tree: Any, p_s: float, p_q: int) -> int:
    """Shape-only price of the packed stream WITHOUT the dense fast path of
    ``expected_pytree_wire_bytes``: the stream always carries the per-tensor
    f32 scale, so at the uncompressed point the packed codecs cost
    ``dense + 4 * n_leaves`` bytes, and ``wire_bytes`` must agree with what
    ``encode`` actually emits.  (Engines never see that point — ``resolve_codec``
    short-circuits it to :class:`IdentityCodec` — but directly constructed
    codecs stay self-consistent.)"""
    return (sum(expected_tensor_wire_bits(x.size, p_s, p_q)
                for x in jax.tree.leaves(tree)) + 7) // 8


@dataclasses.dataclass(frozen=True)
class PackedBitstreamCodec(Codec):
    """The real bit-packed wire format (Alg. 3 serialization).

    Per tensor, in stream order: ``[scale: 32b f32] [k values at
    min(p_q, 32) bits] [k delta-coded sorted indices at ceil(log2 n) bits,
    omitted when k == n]``.  Quantized levels travel offset-binary
    (``level + L``); uncompressed values travel as raw f32 bit patterns.
    Tensors are concatenated bit-level (no per-tensor byte padding) and the
    single trailing partial byte is zero-filled, so

        ``len(encode(tree).payload) == expected_pytree_wire_bytes(tree)``

    holds exactly.  Selection and quantization reuse ``compress_tensor``
    verbatim, making the decode bit-identical to :class:`DenseRefCodec` for
    the same ``(p_s, p_q, rng)``.  Full layout spec: docs/WIRE_FORMAT.md.

    **Fused fast path**: with ``fused=True`` (the default), deterministic
    encodes (``rng is None``) go through the one-pass fused emitter
    ``repro.kernels.ops.fused_wire_encode`` — the ``fused_pack`` Pallas
    kernel on TPU (REPRO_PALLAS_NATIVE=1), its vectorized numpy twin on
    host — which writes the packed words directly at dense-codec speed.
    Stochastic (rng) encodes always take the multi-pass ``compress_tensor``
    pipeline: engines pass the shared sim RNG, so protocol histories keep
    the exact legacy draw order regardless of ``fused``.  ``fused=False``
    keeps the host pipeline as the parity oracle (the way the ``heap``
    scheduler anchors ``batched``); tests/test_fused_pack pins
    fused-vs-oracle stream bit-equality."""

    p_s: float = 1.0
    p_q: int = FLOAT_BITS
    fused: bool = True

    name: ClassVar[str] = "packed"

    def __post_init__(self):
        if not (2 <= self.p_q):
            raise ValueError(f"p_q must be >= 2, got {self.p_q}")

    # -- encode -----------------------------------------------------------
    def encode(self, tree, *, rng=None) -> Wire:
        leaves, treedef = jax.tree.flatten(tree)
        shapes = [np.shape(x) for x in leaves]
        if self.fused and rng is None:
            # imported at call time: repro.kernels.ops pulls in the fused
            # kernel, which needs repro.core.compression — a top-level
            # import here would close that cycle when repro.kernels loads
            # first
            from repro.kernels.ops import fused_wire_encode
            payload = fused_wire_encode(leaves, self.p_s, self.p_q)
        else:
            segments: List[Tuple[np.ndarray, int]] = []
            for x in leaves:
                c = compress_tensor(np.asarray(x), self.p_s, self.p_q, rng)
                segments.extend(self._tensor_segments(c))
            payload = pack_segments(segments)
        return Wire(self.name, payload, len(payload), meta=(treedef, shapes))

    @staticmethod
    def _tensor_segments(c: Dict[str, Any]) -> List[Tuple[np.ndarray, int]]:
        n, p_q = c["n"], c["p_q"]
        values, indices = c["values"], c["indices"]
        k = len(values)
        vbits = min(p_q, FLOAT_BITS)
        scale = np.asarray(c["scale"], np.float32).reshape(1).view(np.uint32)
        # sort by index for delta coding; the scatter in Alg. 4 is
        # order-invariant, so reordering values alongside is lossless
        order = np.argsort(indices, kind="stable")
        idx_s = np.asarray(indices)[order]
        vals_s = np.asarray(values)[order]
        if p_q < FLOAT_BITS:
            L = 2 ** (p_q - 1) - 1
            u_vals = (vals_s.astype(np.int64) + L).astype(np.uint32)
        else:
            u_vals = vals_s.astype(np.float32).view(np.uint32)
        segs = [(scale, FLOAT_BITS), (u_vals, vbits)]
        if k < n:
            deltas = np.empty(k, np.uint32)
            deltas[0] = idx_s[0]
            deltas[1:] = np.diff(idx_s)
            segs.append((deltas, index_bits(n)))
        return segs

    # -- decode -----------------------------------------------------------
    def decode(self, wire: Wire):
        treedef, shapes = wire.meta
        reader = BitReader(wire.payload)
        leaves = [self._read_tensor(reader, shape) for shape in shapes]
        return jax.tree.unflatten(treedef, leaves)

    def _read_tensor(self, reader: BitReader, shape) -> np.ndarray:
        n = int(np.prod(shape)) if shape else 1
        k = topk_count(n, self.p_s)
        vbits = min(self.p_q, FLOAT_BITS)
        scale = float(reader.read(1, FLOAT_BITS).view(np.float32)[0])
        u_vals = reader.read(k, vbits)
        if self.p_q < FLOAT_BITS:
            L = 2 ** (self.p_q - 1) - 1
            values = (u_vals.astype(np.int64) - L).astype(np.int32)
        else:
            values = u_vals.view(np.float32)
        if k < n:
            indices = np.cumsum(reader.read(k, index_bits(n)).astype(np.int64))
        else:
            indices = np.arange(n, dtype=np.int64)
        return decompress_tensor({"values": values, "indices": indices,
                                  "scale": scale, "shape": tuple(shape),
                                  "p_q": self.p_q, "n": n})

    def wire_bytes(self, tree) -> int:
        return _packed_price(tree, self.p_s, self.p_q)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
CODECS: Dict[str, Type[Codec]] = {
    cls.name: cls for cls in (IdentityCodec, DenseRefCodec,
                              ThresholdGraphCodec, PackedBitstreamCodec)
}


@functools.lru_cache(maxsize=256)
def _make_codec(name: str, p_s: float, p_q: int, iters: int) -> Codec:
    if name == "threshold":
        return ThresholdGraphCodec(p_s, p_q, iters)
    return CODECS[name](p_s, p_q) if name != "identity" else IdentityCodec()


def resolve_codec(name: str, p_s: float = 1.0, p_q: int = FLOAT_BITS,
                  iters: int = 12) -> Codec:
    """Bind a codec family name to an ``(p_s, p_q)`` operating point.

    The uncompressed point short-circuits to :class:`IdentityCodec` for
    every family — that is the simulators' historical dense fast path, and
    it keeps byte accounting (and RNG draw order) identical across codec
    selections when a protocol round happens to be uncompressed.
    Instances are cached: codecs are frozen/stateless, so sharing is safe.
    """
    if name not in CODECS:
        raise ValueError(
            f"unknown codec {name!r}; expected one of {sorted(CODECS)}")
    if p_s >= 1.0 and p_q >= FLOAT_BITS:
        return _make_codec("identity", 1.0, FLOAT_BITS, iters)
    return _make_codec(name, float(p_s), int(p_q), int(iters))
