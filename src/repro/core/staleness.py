"""Staleness-weighted cached aggregation (paper Eqs. 6-10)."""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def staleness_weight(staleness, a: float = 0.5):
    """Eq. 6: S(t - h_c) = (t - h_c + 1)^(-a)."""
    return (jnp.asarray(staleness, jnp.float32) + 1.0) ** (-a)


def weighted_average(updates: Sequence[Any], staleness: Sequence[float],
                     n_samples: Sequence[float], a: float = 0.5) -> Any:
    """Eq. 7: u = sum_c S(t-h_c) n_c w_c / sum_c S(t-h_c) n_c."""
    s = staleness_weight(jnp.asarray(staleness), a)
    n = jnp.asarray(n_samples, jnp.float32)
    wts = s * n
    wts = wts / jnp.sum(wts)

    def avg(*leaves):
        return sum(w * l for w, l in zip(wts, leaves))

    return jax.tree.map(avg, *updates)


def mixing_alpha(staleness: Sequence[float], alpha: float, a: float = 0.5):
    """Eqs. 8-9: alpha^t = alpha * S(mean staleness)."""
    delta = jnp.mean(jnp.asarray(staleness, jnp.float32))
    return alpha * staleness_weight(delta, a)


def merge_global(w_global: Any, u: Any, alpha_t) -> Any:
    """Eq. 10: w^{t+1} = alpha^t u + (1 - alpha^t) w^t."""
    return jax.tree.map(lambda wu, wg: alpha_t * wu + (1.0 - alpha_t) * wg,
                        u, w_global)


def aggregate_cache(w_global: Any, cache: List[Tuple[Any, int, int]],
                    t: int, alpha: float, a: float = 0.5) -> Any:
    """Full server aggregation step over cached (update, h_c, n_c) entries."""
    updates = [c[0] for c in cache]
    staleness = [t - c[1] for c in cache]
    n_samples = [c[2] for c in cache]
    u = weighted_average(updates, staleness, n_samples, a)
    a_t = mixing_alpha(staleness, alpha, a)
    return merge_global(w_global, u, a_t)
