"""Staleness-weighted cached aggregation (paper Eqs. 6-10)."""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def staleness_weight(staleness, a: float = 0.5):
    """Eq. 6: S(t - h_c) = (t - h_c + 1)^(-a)."""
    return (jnp.asarray(staleness, jnp.float32) + 1.0) ** (-a)


def weighted_average(updates: Sequence[Any], staleness: Sequence[float],
                     n_samples: Sequence[float], a: float = 0.5) -> Any:
    """Eq. 7: u = sum_c S(t-h_c) n_c w_c / sum_c S(t-h_c) n_c."""
    s = staleness_weight(jnp.asarray(staleness), a)
    n = jnp.asarray(n_samples, jnp.float32)
    wts = s * n
    wts = wts / jnp.sum(wts)

    def avg(*leaves):
        return sum(w * l for w, l in zip(wts, leaves))

    return jax.tree.map(avg, *updates)


def mixing_alpha(staleness: Sequence[float], alpha: float, a: float = 0.5):
    """Eqs. 8-9: alpha^t = alpha * S(mean staleness)."""
    delta = jnp.mean(jnp.asarray(staleness, jnp.float32))
    return alpha * staleness_weight(delta, a)


def merge_global(w_global: Any, u: Any, alpha_t) -> Any:
    """Eq. 10: w^{t+1} = alpha^t u + (1 - alpha^t) w^t."""
    return jax.tree.map(lambda wu, wg: alpha_t * wu + (1.0 - alpha_t) * wg,
                        u, w_global)


@jax.jit
def _aggregate_cache_jit(w_global: Any, updates: Tuple[Any, ...],
                         staleness: jax.Array, n_samples: jax.Array,
                         alpha, a) -> Any:
    """Fused Eqs. 6-10: one compiled program per (K, tree) shape instead of
    ~20 eager ops per leaf per round — the aggregation showed up as the
    top host-dispatch cost in large-N engine runs."""
    s = (staleness + 1.0) ** (-a)
    wts = s * n_samples
    wts = wts / jnp.sum(wts)

    def avg(*leaves):
        return sum(w * l for w, l in zip(wts, leaves))

    u = jax.tree.map(avg, *updates)
    a_t = alpha * (jnp.mean(staleness) + 1.0) ** (-a)
    return jax.tree.map(lambda wu, wg: a_t * wu + (1.0 - a_t) * wg,
                        u, w_global)


def aggregate_cache(w_global: Any, cache: List[Tuple[Any, int, int]],
                    t: int, alpha: float, a: float = 0.5) -> Any:
    """Full server aggregation step over cached (update, h_c, n_c) entries."""
    updates = tuple(c[0] for c in cache)
    staleness = np.asarray([t - c[1] for c in cache], np.float32)
    n_samples = np.asarray([c[2] for c in cache], np.float32)
    return _aggregate_cache_jit(w_global, updates, staleness, n_samples,
                                alpha, a)


# ----------------------------------------------------------------------
# Stacked (wave) variant: the K cached updates arrive as ONE leading-axis
# stack per leaf instead of a K-tuple of trees.  Passing K*L separate
# leaves made _aggregate_cache_jit's host-side arg flattening the dominant
# per-round dispatch cost at large N; the stacked form is a handful of
# args regardless of K.  The reduction runs as a tensordot over the
# stacked axis — float reassociation vs. the tuple kernel's sequential
# sum is covered by handler_mode="wave"'s relaxed-parity contract.
# ----------------------------------------------------------------------
def stacked_staleness_weights(staleness, n_samples, a: float = 0.5):
    """Eqs. 6-7 weights, normalized — shared by the event-driven wave
    aggregation and the datacenter fed_step combine."""
    s = staleness_weight(staleness, a)
    wts = s * jnp.asarray(n_samples, jnp.float32)
    return wts / jnp.sum(wts)


@jax.jit
def _aggregate_cache_stacked_jit(w_global: Any, stacked: Any,
                                 staleness: jax.Array, n_samples: jax.Array,
                                 alpha, a) -> Any:
    wts = stacked_staleness_weights(staleness, n_samples, a)
    u = jax.tree.map(
        lambda st: jnp.tensordot(wts, st.astype(jnp.float32), axes=1),
        stacked)
    a_t = alpha * (jnp.mean(staleness) + 1.0) ** (-a)
    return jax.tree.map(lambda wu, wg: a_t * wu + (1.0 - a_t) * wg,
                        u, w_global)


def aggregate_cache_stacked(w_global: Any, cache: List[Tuple[Any, int, int]],
                            t: int, alpha: float, a: float = 0.5) -> Any:
    """Wave-mode aggregation: host-stack the K updates once, then one
    jitted call with a K-independent argument count."""
    stacked = jax.tree.map(
        lambda *ls: np.stack([np.asarray(l) for l in ls]),
        *(c[0] for c in cache))
    staleness = np.asarray([t - c[1] for c in cache], np.float32)
    n_samples = np.asarray([c[2] for c in cache], np.float32)
    return _aggregate_cache_stacked_jit(w_global, stacked, staleness,
                                        n_samples, alpha, a)
