"""Staleness-weighted cached aggregation (paper Eqs. 6-10)."""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def staleness_weight(staleness, a: float = 0.5):
    """Eq. 6: S(t - h_c) = (t - h_c + 1)^(-a)."""
    return (jnp.asarray(staleness, jnp.float32) + 1.0) ** (-a)


def weighted_average(updates: Sequence[Any], staleness: Sequence[float],
                     n_samples: Sequence[float], a: float = 0.5) -> Any:
    """Eq. 7: u = sum_c S(t-h_c) n_c w_c / sum_c S(t-h_c) n_c."""
    s = staleness_weight(jnp.asarray(staleness), a)
    n = jnp.asarray(n_samples, jnp.float32)
    wts = s * n
    wts = wts / jnp.sum(wts)

    def avg(*leaves):
        return sum(w * l for w, l in zip(wts, leaves))

    return jax.tree.map(avg, *updates)


def mixing_alpha(staleness: Sequence[float], alpha: float, a: float = 0.5):
    """Eqs. 8-9: alpha^t = alpha * S(mean staleness)."""
    delta = jnp.mean(jnp.asarray(staleness, jnp.float32))
    return alpha * staleness_weight(delta, a)


def merge_global(w_global: Any, u: Any, alpha_t) -> Any:
    """Eq. 10: w^{t+1} = alpha^t u + (1 - alpha^t) w^t."""
    return jax.tree.map(lambda wu, wg: alpha_t * wu + (1.0 - alpha_t) * wg,
                        u, w_global)


@jax.jit
def _aggregate_cache_jit(w_global: Any, updates: Tuple[Any, ...],
                         staleness: jax.Array, n_samples: jax.Array,
                         alpha, a) -> Any:
    """Fused Eqs. 6-10: one compiled program per (K, tree) shape instead of
    ~20 eager ops per leaf per round — the aggregation showed up as the
    top host-dispatch cost in large-N engine runs."""
    s = (staleness + 1.0) ** (-a)
    wts = s * n_samples
    wts = wts / jnp.sum(wts)

    def avg(*leaves):
        return sum(w * l for w, l in zip(wts, leaves))

    u = jax.tree.map(avg, *updates)
    a_t = alpha * (jnp.mean(staleness) + 1.0) ** (-a)
    return jax.tree.map(lambda wu, wg: a_t * wu + (1.0 - a_t) * wg,
                        u, w_global)


def aggregate_cache(w_global: Any, cache: List[Tuple[Any, int, int]],
                    t: int, alpha: float, a: float = 0.5) -> Any:
    """Full server aggregation step over cached (update, h_c, n_c) entries."""
    updates = tuple(c[0] for c in cache)
    staleness = np.asarray([t - c[1] for c in cache], np.float32)
    n_samples = np.asarray([c[2] for c in cache], np.float32)
    return _aggregate_cache_jit(w_global, updates, staleness, n_samples,
                                alpha, a)


# ----------------------------------------------------------------------
# Stacked (wave) variant: the K cached updates arrive as ONE leading-axis
# stack per leaf instead of a K-tuple of trees.  Passing K*L separate
# leaves made _aggregate_cache_jit's host-side arg flattening the dominant
# per-round dispatch cost at large N; the stacked form is a handful of
# args regardless of K.  The reduction runs as a tensordot over the
# stacked axis — float reassociation vs. the tuple kernel's sequential
# sum is covered by handler_mode="wave"'s relaxed-parity contract.
# ----------------------------------------------------------------------
def stacked_staleness_weights(staleness, n_samples, a: float = 0.5):
    """Eqs. 6-7 weights, normalized — shared by the event-driven wave
    aggregation and the datacenter fed_step combine."""
    s = staleness_weight(staleness, a)
    wts = s * jnp.asarray(n_samples, jnp.float32)
    return wts / jnp.sum(wts)


@jax.jit
def _aggregate_cache_stacked_jit(w_global: Any, stacked: Any,
                                 staleness: jax.Array, n_samples: jax.Array,
                                 alpha, a) -> Any:
    wts = stacked_staleness_weights(staleness, n_samples, a)
    u = jax.tree.map(
        lambda st: jnp.tensordot(wts, st.astype(jnp.float32), axes=1),
        stacked)
    a_t = alpha * (jnp.mean(staleness) + 1.0) ** (-a)
    return jax.tree.map(lambda wu, wg: a_t * wu + (1.0 - a_t) * wg,
                        u, w_global)


def aggregate_cache_stacked(w_global: Any, cache: List[Tuple[Any, int, int]],
                            t: int, alpha: float, a: float = 0.5) -> Any:
    """Wave-mode aggregation: host-stack the K updates once, then one
    jitted call with a K-independent argument count."""
    stacked = jax.tree.map(
        lambda *ls: np.stack([np.asarray(l) for l in ls]),
        *(c[0] for c in cache))
    staleness = np.asarray([t - c[1] for c in cache], np.float32)
    n_samples = np.asarray([c[2] for c in cache], np.float32)
    return _aggregate_cache_stacked_jit(w_global, stacked, staleness,
                                        n_samples, alpha, a)


# ----------------------------------------------------------------------
# Sharded (mesh) variant: the stacked Eqs. 6-10 reduction partitioned over
# a 1-D device mesh.  The weight pytree is flattened to ONE f32 vector and
# split into equal column blocks (one per mesh device); each shard runs the
# same per-element program as the single-host stacked kernel — the K-sized
# tensordot reduction and the Eq. 10 merge touch each element exactly once,
# with the identical per-element operand order — so the sharded result is
# expected bit-identical to `_aggregate_cache_stacked_jit` (the mesh-parity
# suite tests/test_sharded_server.py allows at most 1 ulp for XLA-version
# slack in how the fused multiply-adds are grouped).
# ----------------------------------------------------------------------
def _flatten_f32(tree: Any) -> Tuple[np.ndarray, Tuple[Any, List[Tuple]]]:
    """(flat f32 vector, (treedef, leaf shapes)) of a weight pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrs = [np.asarray(l, np.float32) for l in leaves]
    vec = (np.concatenate([a.ravel() for a in arrs]) if arrs
           else np.zeros(0, np.float32))
    return vec, (treedef, [a.shape for a in arrs])


def _unflatten_f32(vec: np.ndarray, spec) -> Any:
    treedef, shapes = spec
    out, o = [], 0
    for sh in shapes:
        n = int(np.prod(sh, dtype=np.int64))
        out.append(np.asarray(vec[o:o + n]).reshape(sh))
        o += n
    return jax.tree_util.tree_unflatten(treedef, out)


def _sharded_agg_body(wg_loc, stacked_loc, staleness, n_samples, alpha, a):
    """Per-shard flat Eqs. 6-10: ``wg_loc`` / ``stacked_loc`` carry one
    column block of the flattened weights, the scalar inputs are
    replicated.  Identical jnp ops to ``_aggregate_cache_stacked_jit``, so
    every shard's weights/a_t match the single-host kernel's bit-for-bit."""
    wts = stacked_staleness_weights(staleness, n_samples, a)
    u_loc = jnp.tensordot(wts, stacked_loc, axes=1)
    a_t = alpha * (jnp.mean(staleness) + 1.0) ** (-a)
    return a_t * u_loc + (1.0 - a_t) * wg_loc


def _flat_cache(w_global: Any, cache: List[Tuple[Any, int, int]], t: int,
                n_shards: int):
    """Host-side prep shared by the mesh and reference sharded paths:
    flatten + zero-pad weights/stack to a multiple of ``n_shards``."""
    wg, spec = _flatten_f32(w_global)
    stk = np.stack([_flatten_f32(c[0])[0] for c in cache])
    size = wg.size
    pad = (-size) % n_shards
    if pad:
        wg = np.concatenate([wg, np.zeros(pad, np.float32)])
        stk = np.concatenate(
            [stk, np.zeros((len(cache), pad), np.float32)], axis=1)
    staleness = np.asarray([t - c[1] for c in cache], np.float32)
    n_samples = np.asarray([c[2] for c in cache], np.float32)
    return wg, stk, staleness, n_samples, size, spec


def make_sharded_aggregator(mesh):
    """Compiled sharded aggregation over ``mesh``'s (single) axis.

    Returns ``agg(w_global, cache, t, alpha, a) -> new w_global``, where
    the flat Eqs. 6-10 body runs as a ``shard_map``: the weight vector and
    the stacked cache's column axis are partitioned across the mesh
    devices, the K-vector of staleness weights is computed replicated, and
    each device reduces its own block.  Used by
    ``repro.core.server.ShardedTeasqServer`` over a
    ``--xla_force_host_platform_device_count`` host mesh."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import shard_map

    axis = mesh.axis_names[0]
    m = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    body = shard_map(
        _sharded_agg_body, mesh=mesh,
        in_specs=(P(axis), P(None, axis), P(), P(), P(), P()),
        out_specs=P(axis))
    jitted = jax.jit(body)

    def agg(w_global, cache, t, alpha, a=0.5):
        wg, stk, staleness, n_samples, size, spec = _flat_cache(
            w_global, cache, t, m)
        out = np.asarray(jitted(wg, stk, staleness, n_samples,
                                jnp.float32(alpha), jnp.float32(a)))
        return _unflatten_f32(out[:size], spec)

    return agg


_sharded_body_jit = jax.jit(_sharded_agg_body)


def aggregate_cache_sharded_ref(w_global: Any,
                                cache: List[Tuple[Any, int, int]], t: int,
                                alpha: float, a: float = 0.5,
                                n_shards: int = 2) -> Any:
    """Mesh-free replay of the sharded reduction: the same flat split into
    ``n_shards`` column blocks, each reduced by the same per-shard body on
    the default device.  tests/test_sharded_server.py property-checks this
    chunked reduction against the single-host kernels in-process (no
    multi-device subprocess needed), and the subprocess mesh tests pin the
    real ``shard_map`` against it."""
    wg, stk, staleness, n_samples, size, spec = _flat_cache(
        w_global, cache, t, n_shards)
    block = wg.size // n_shards
    outs = [np.asarray(_sharded_body_jit(
        wg[s * block:(s + 1) * block], stk[:, s * block:(s + 1) * block],
        staleness, n_samples, jnp.float32(alpha), jnp.float32(a)))
        for s in range(n_shards)]
    return _unflatten_f32(np.concatenate(outs)[:size], spec)
