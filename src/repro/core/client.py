"""Device-side local update (paper Alg. 1 device process, Eq. 5).

E local epochs of minibatch SGD on
    f_k(w; x) + (mu/2) ||w - w^t||^2
where w^t is the (decompressed) global model pulled from the server.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("loss_fn", "lr", "mu"))
def _prox_sgd_step(params: Any, anchor: Any, batch: Dict[str, jax.Array],
                   loss_fn: Callable, lr: float, mu: float
                   ) -> Tuple[Any, jax.Array]:
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)

    def upd(p, g, a):
        return p - lr * (g + mu * (p - a))

    return jax.tree.map(upd, params, grads, anchor), loss


def local_update(w_global: Any, data_x: np.ndarray, data_y: np.ndarray,
                 loss_fn: Callable, *, epochs: int, batch_size: int,
                 lr: float, mu: float, rng: np.random.RandomState
                 ) -> Tuple[Any, float, int]:
    """Run E epochs of prox-SGD from w_global. Returns (w_local, last_loss,
    n_steps). ``loss_fn(params, batch)`` is the task loss."""
    params = w_global
    n = len(data_y)
    steps = 0
    loss = float("nan")
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            sel = order[s:s + batch_size]
            batch = {"images": jnp.asarray(data_x[sel]),
                     "labels": jnp.asarray(data_y[sel])}
            params, l = _prox_sgd_step(params, w_global, batch, loss_fn, lr, mu)
            loss = float(l)
            steps += 1
    return params, loss, steps
