"""TEASQ-Fed as a first-class mesh feature: one jit-able federated round.

Datacenter mapping of the protocol (DESIGN.md §3b): the mesh's fed axes
(``data`` [+ ``pod``]) are partitioned into G federated groups; one round is

  1. every group runs E prox-SGD local steps from the global params on its
     own microbatches (Eq. 5);
  2. each group's model delta is compressed in-graph with the paper's
     Top-K (block-threshold) + QSGD operator;
  3. deltas are exchanged and combined with the staleness weights of
     Eqs. 6-10 to form the new global params.

Step 3 has three collective schedules (the §Perf hillclimb lever):

  * ``gather_q``  — paper-faithful: all-gather the *quantized int8* deltas
    over the fed axes + local dequant/weighted-sum (matches the FL star
    topology where the server receives K compressed models). Wire bytes
    = G * |params|/4 per device (sparsity savings are additionally real on
    a packed wire; in dense HLO layout they are reported analytically).
  * ``gather_f32`` — TEA-Fed (no compression) baseline: f32 all-gather.
  * ``psum``       — beyond-paper: pre-weighted dense reduce (ring
    all-reduce, 2*|params| bytes) — cheaper than any gather at G >= 8 but
    requires a reduction network, which the paper's wireless setting lacks.

Without an active mesh the same code runs unsharded (vmap over groups) so
CPU tests can verify all schedules agree.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.staleness import mixing_alpha, stacked_staleness_weights
from repro.sharding.rules import (Rules, active_rules, logical_axes_for,
                                  shard_map)


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_groups: int = 8             # G (must equal prod(fed mesh axes) on mesh)
    local_steps: int = 1          # E
    lr: float = 1e-3
    mu: float = 0.01              # prox weight (Eq. 5)
    alpha: float = 0.6            # mixing (Eq. 9)
    a: float = 0.5                # staleness exponent (Eq. 6)
    p_s: float = 0.25             # sparsification keep-ratio
    p_q: int = 8                  # quantization bits (8 -> int8 wire dtype)
    schedule: str = "gather_q"    # gather_q | gather_f32 | psum
    threshold_iters: int = 12
    # within-group parallelism: "tp" (Megatron tensor parallel) or "dp"
    # (replicate weights, split the group batch over the model axis — wins
    # when the model fits per-chip; see EXPERIMENTS.md §Perf pair C)
    group_parallelism: str = "tp"


# ----------------------------------------------------------------------
# in-graph compression primitives (TPU-adapted: no sort)
# ----------------------------------------------------------------------
def approx_topk_threshold(absx: jax.Array, p_s: float, iters: int) -> jax.Array:
    """Binary-search the magnitude threshold keeping ~p_s of entries.
    O(iters * n) elementwise — the TPU-native replacement for global sort."""
    lo = jnp.zeros((), jnp.float32)
    hi = jnp.max(absx).astype(jnp.float32) + 1e-12

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        frac = jnp.mean((absx >= mid).astype(jnp.float32))
        return jnp.where(frac > p_s, mid, lo), jnp.where(frac > p_s, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def compress_delta(x: jax.Array, fed: FedConfig) -> Tuple[jax.Array, jax.Array]:
    """-> (intN levels with zeros below threshold, f32 scale).
    p_q <= 4 uses the packed s4 wire dtype (half the int8 bytes)."""
    absx = jnp.abs(x.astype(jnp.float32))
    thr = approx_topk_threshold(absx, fed.p_s, fed.threshold_iters)
    mask = absx >= thr
    kept = jnp.where(mask, x.astype(jnp.float32), 0.0)
    L = 2 ** (fed.p_q - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(kept)), 1e-12)
    wire_dtype = jnp.int4 if fed.p_q <= 4 else jnp.int8
    levels = jnp.clip(jnp.round(kept / scale * L), -L, L).astype(wire_dtype)
    return levels, scale


def decompress_delta(levels: jax.Array, scale: jax.Array, fed: FedConfig,
                     dtype) -> jax.Array:
    L = 2 ** (fed.p_q - 1) - 1
    return (levels.astype(jnp.float32) * scale / L).astype(dtype)


# ----------------------------------------------------------------------
def _group_local_train(w0: Any, batches: Any, loss_fn: Callable,
                       fed: FedConfig) -> Tuple[Any, jax.Array]:
    """E prox-SGD steps for ONE group. batches: leaves (E, mb, ...)."""

    def step(w, mb):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, mb))(w)
        w = jax.tree.map(
            lambda p, g, a0: (p - fed.lr * (g + fed.mu * (p - a0))).astype(p.dtype),
            w, grads, w0)
        return w, loss

    w_final, losses = jax.lax.scan(step, w0, batches)
    return w_final, losses.mean()


def _fed_axes(rules: Optional[Rules]) -> Tuple[str, ...]:
    if rules is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)


def fed_wire_bytes(params: Any, fed: FedConfig, n_groups: int) -> Dict[str, float]:
    """Analytic wire accounting (per round, whole system) for EXPERIMENTS.md."""
    n = sum(x.size for x in jax.tree.leaves(params))
    dense_f32 = 4.0 * n * n_groups
    idx_bits = math.ceil(math.log2(max(n, 2)))
    packed = n_groups * (fed.p_s * n * (fed.p_q + idx_bits)) / 8.0
    dense_q = n_groups * n * fed.p_q / 8.0
    return {"dense_f32": dense_f32, "dense_quant": dense_q,
            "packed_sparse_quant": packed,
            "compression_x": dense_f32 / packed}


def make_fed_train_step(loss_fn: Callable, fed: FedConfig
                        ) -> Callable:
    """Build fed_round(params, batch, staleness) -> (params', metrics).

    ``loss_fn(params, batch) -> scalar``.  ``batch`` leaves are (B, ...) with
    B divisible by n_groups * local_steps; ``staleness`` is (G,) int32.
    """

    def fed_round(params, batch, staleness):
        rules = active_rules()
        G, E = fed.n_groups, fed.local_steps

        def split(x):  # (B, ...) -> (G, E, B/(G*E), ...)
            return x.reshape((G, E, x.shape[0] // (G * E)) + x.shape[1:])

        gbatch = jax.tree.map(split, batch)

        # Inside the group-local region, ``batch``/``seq`` constraints must
        # NOT claim the fed axes (they belong to the group dim) — otherwise
        # GSPMD bounces activations between conflicting shardings
        # ("involuntary full rematerialization").  §Perf iteration 1.
        from repro.sharding.rules import use_rules
        if rules is None:
            local_rules = None
            fed_axes = ()
        elif fed.group_parallelism == "dp":
            # replicate weights over 'model'; split the group batch over it
            local_rules = rules.with_overrides(
                batch="model", seq=None, heads=None, kv_heads=None,
                ffn=None, vocab=None, experts=None, ssm_heads=None)
            fed_axes = _fed_axes(rules)
        else:
            local_rules = rules.with_overrides(batch=None, seq=None)
            fed_axes = _fed_axes(rules)

        # broadcast params to groups; shard group axis over the fed axes
        def bcast(path, x):
            y = jnp.broadcast_to(x[None], (G,) + x.shape)
            if local_rules is not None:
                keys = "/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                                for p in path)
                logical = ("fed_group",) + logical_axes_for(keys, x.ndim)
                y = jax.lax.with_sharding_constraint(
                    y, local_rules.sharding(logical, y.shape))
            return y

        w_groups = jax.tree_util.tree_map_with_path(bcast, params)
        if rules is not None:
            # gbatch leaves: (G, E, b, ...) — in dp mode the per-group batch
            # dim (2) shards over 'model'
            bspec = (("fed_group", None, "batch")
                     if fed.group_parallelism == "dp" else ("fed_group",))
            gbatch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, local_rules.sharding(
                        bspec + (None,) * (x.ndim - len(bspec)), x.shape)),
                gbatch)
        vmap_kw = {}
        if fed_axes:
            # shard the vmapped group dim over the fed axes inside any inner
            # shard_map (the MoE expert-parallel block)
            vmap_kw["spmd_axis_name"] = fed_axes
        with use_rules(local_rules):
            w_local, losses = jax.vmap(
                lambda w, b: _group_local_train(w, b, loss_fn, fed),
                **vmap_kw)(w_groups, gbatch)

        # 2. per-group compressed deltas
        delta = jax.tree.map(lambda wl, w0: wl - w0[None], w_local, params)
        # Eqs. 6-7 over equal-sized groups (n_c == 1): the same normalized
        # weights the event-driven wave aggregation uses.
        wts = stacked_staleness_weights(staleness, jnp.ones_like(
            jnp.asarray(staleness, jnp.float32)), fed.a)  # (G,)
        a_t = mixing_alpha(staleness, fed.alpha, fed.a)

        # 3. exchange + staleness-weighted combine
        if rules is not None and fed.schedule.startswith("gather") \
                and _fed_axes(rules):
            # paper's star-topology wire pattern: explicit all-gather of the
            # (quantized) per-group deltas over the fed axes.
            new_params = _force_gather(delta, params, wts, a_t, fed, rules)
        elif fed.schedule == "gather_q":
            def combine(d, w0):
                cvm = jax.vmap(lambda x: compress_delta(x, fed))
                levels, scales = cvm(d.reshape(G, -1))
                dq = jax.vmap(lambda l, s: decompress_delta(l, s, fed,
                                                            jnp.float32))(
                    levels, scales)
                u = jnp.einsum("gn,g->n", dq, wts).reshape(w0.shape)
                return (w0 + a_t * u).astype(w0.dtype)
            new_params = jax.tree.map(combine, delta, params)
        else:  # psum / gather_f32 without mesh: dense weighted reduce
            def combine(d, w0):
                u = jnp.einsum("g...,g->...", d.astype(jnp.float32), wts)
                return (w0 + a_t * u).astype(w0.dtype)
            new_params = jax.tree.map(combine, delta, params)
        metrics = {"local_loss": losses.mean(),
                   "alpha_t": a_t,
                   "delta_norm": _tree_norm(delta)}
        return new_params, metrics

    return fed_round


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _force_gather(delta, params, wts, a_t, fed: FedConfig,
                  rules: Rules):
    """Recompute the combine inside shard_map with an explicit all_gather of
    the (optionally quantized) per-group deltas over the fed axes, so the
    compiled collective schedule matches the FL star topology."""
    mesh = rules.mesh
    fed_axes = _fed_axes(rules)
    G = fed.n_groups

    flat, treedef = jax.tree_util.tree_flatten_with_path(delta)
    new_flat = []
    for path, d in flat:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        logical = logical_axes_for(keys, d.ndim - 1)
        pspec = rules.spec(logical, d.shape[1:])
        in_spec = P(fed_axes, *pspec)
        w0 = None
        for p2, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            k2 = "/".join(str(getattr(q, "key", getattr(q, "idx", ""))) for q in p2)
            if k2 == keys:
                w0 = leaf
                break

        quant = fed.schedule == "gather_q"

        def body(d_loc, w0_loc, wts_r, a_t_r, _quant=quant):
            # d_loc: (G/|fed|, shard...) ; gather the group axis
            if _quant:
                lv, sc = jax.vmap(lambda x: compress_delta(x, fed))(
                    d_loc.reshape(d_loc.shape[0], -1))
                lv = jax.lax.all_gather(lv, fed_axes, axis=0, tiled=True)
                sc = jax.lax.all_gather(sc, fed_axes, axis=0, tiled=True)
                dq = jax.vmap(lambda l, s: decompress_delta(l, s, fed,
                                                            jnp.float32))(lv, sc)
            else:
                dq = jax.lax.all_gather(d_loc, fed_axes, axis=0, tiled=True)
                dq = dq.reshape(G, -1).astype(jnp.float32)
            u = jnp.einsum("gn,g->n", dq, wts_r).reshape(w0_loc.shape)
            return (w0_loc + a_t_r * u).astype(w0_loc.dtype)

        out = shard_map(body, mesh=mesh,
                            in_specs=(in_spec, pspec, P(), P()),
                            out_specs=pspec, check_vma=False)(d, w0, wts, a_t)
        new_flat.append(out)
    return jax.tree_util.tree_unflatten(treedef, [x for x in new_flat])
