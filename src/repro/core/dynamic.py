"""Algorithm 5: dynamic data compression — greedy (p_s, p_q) search + decay.

Greedy profiling (lines 1-12): starting from no compression, alternately
increase the sparsification compression rate while the accuracy drop on a
profiling model stays within ``theta``, then step up quantization, backing
off sparsification when the combination overshoots.

Decay schedule (lines 13-18): start one notch *more* compressed than the
searched static point (p_{s,0}, p_{q,0}) and decay the compression every
``step_size`` rounds toward no compression — aggressive wire savings early,
full fidelity late.  (The paper's prose is ambiguous about decay direction;
Fig. 7/Table 5 — TEASQ faster than TEA-Fed early AND higher final accuracy
than TEAStatic — is only consistent with decaying *toward less compression*,
which is what we implement.)

Beyond the paper: :func:`greedy_search_per_tier` runs one budgeted search
per bandwidth tier (monotone: slower links end at least as compressed),
feeding the ``tier_aware`` per-device codec policy in
``repro.fl.policies``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

# candidate sets, ordered from least to most compressed (paper Set_s / Set_q)
DEFAULT_SET_S: Tuple[float, ...] = (1.0, 0.5, 0.25, 0.1, 0.05, 0.01)
DEFAULT_SET_Q: Tuple[int, ...] = (32, 16, 8, 4)


@dataclasses.dataclass
class CompressionSchedule:
    """Per-round (p_s, p_q) from the decayed dynamic schedule."""
    p_s0_idx: int
    p_q0_idx: int
    step_size: int
    set_s: Sequence[float] = DEFAULT_SET_S
    set_q: Sequence[int] = DEFAULT_SET_Q

    def at_round(self, t: int) -> Tuple[float, int]:
        decay = t // self.step_size
        si = max(0, self.p_s0_idx - decay)
        qi = max(0, self.p_q0_idx - decay)
        return self.set_s[si], self.set_q[qi]


def greedy_search(eval_acc: Callable[[float, int], float],
                  theta: float,
                  set_s: Sequence[float] = DEFAULT_SET_S,
                  set_q: Sequence[int] = DEFAULT_SET_Q,
                  ) -> Tuple[int, int, List[Tuple[float, int, float]]]:
    """Algorithm 5 lines 1-12.

    ``eval_acc(p_s, p_q)`` returns test accuracy of the profiling model after
    a compress->decompress round trip.  Returns (idx_s, idx_q) of the chosen
    static point plus the search trace.
    """
    base_acc = eval_acc(1.0, 32)
    floor = base_acc - theta
    trace: List[Tuple[float, int, float]] = []

    si, qi = 0, 0  # least compressed
    # lines 5-7: push sparsification alone as far as the budget allows
    while si + 1 < len(set_s):
        acc = eval_acc(set_s[si + 1], set_q[qi])
        trace.append((set_s[si + 1], set_q[qi], acc))
        if acc >= floor:
            si += 1
        else:
            break

    # lines 4-12: alternately push quantization, backing off sparsification
    while qi + 1 < len(set_q):
        acc = eval_acc(set_s[si], set_q[qi + 1])
        trace.append((set_s[si], set_q[qi + 1], acc))
        if acc >= floor:
            qi += 1
            # try to push sparsification further at the new quantization
            while si + 1 < len(set_s):
                acc = eval_acc(set_s[si + 1], set_q[qi])
                trace.append((set_s[si + 1], set_q[qi], acc))
                if acc >= floor:
                    si += 1
                else:
                    break
        else:
            # back off sparsification until the combo fits again (lines 9-11)
            backed = False
            si_save = si
            while si > 0:
                si -= 1
                acc = eval_acc(set_s[si], set_q[qi + 1])
                trace.append((set_s[si], set_q[qi + 1], acc))
                if acc >= floor:
                    qi += 1
                    backed = True
                    break
            if not backed:
                si = si_save   # quantization step unaffordable at any p_s
                break
    return si, qi, trace


def greedy_search_per_tier(eval_acc: Callable[[float, int], float],
                           theta: float,
                           bandwidth_scales: Sequence[float],
                           set_s: Sequence[float] = DEFAULT_SET_S,
                           set_q: Sequence[int] = DEFAULT_SET_Q,
                           ) -> Tuple[List[Tuple[int, int]],
                                      List[List[Tuple[float, int, float]]]]:
    """Per-tier extension of Algorithm 5 for heterogeneous fleets.

    Tier ``i`` (link scaling ``bandwidth_scales[i]``; < 1 = slower) gets its
    own greedy search with accuracy budget ``theta * max(1, 1/b_i)`` — a
    link with 1/4 the bandwidth buys its 4x wire saving with a
    proportionally larger accuracy allowance, which is the wire-cost/model-
    quality trade TimelyFL-style heterogeneity adaptation makes per device.
    Tiers are searched fastest-first with a monotone clamp: a slower tier is
    never *less* compressed than a faster one, so per-transfer wire bytes
    are non-increasing as links get slower (the property the ``tier_aware``
    codec policy and its tests rely on).

    Returns ``(points, traces)`` in input tier order, where ``points[i] =
    (si, qi)`` indexes ``set_s`` / ``set_q``.

    ``eval_acc`` is memoized per operating point across the tier searches
    (each profile eval is a full codec roundtrip + model eval — seconds on
    CPU — and every tier's search revisits the baseline and the shallow
    points), so N tiers cost roughly one search's worth of *distinct*
    evals, and all tiers judge a point by the same measured accuracy.
    """
    scales = [float(b) for b in bandwidth_scales]
    memo: dict = {}

    def cached_eval(p_s: float, p_q: int) -> float:
        key = (p_s, p_q)
        if key not in memo:
            memo[key] = eval_acc(p_s, p_q)
        return memo[key]

    order = sorted(range(len(scales)), key=lambda i: -scales[i])
    points: List[Tuple[int, int]] = [(0, 0)] * len(scales)
    traces: List[List[Tuple[float, int, float]]] = [[] for _ in scales]
    prev_s = prev_q = 0
    for i in order:
        tier_theta = theta * max(1.0, 1.0 / max(scales[i], 1e-9))
        si, qi, trace = greedy_search(cached_eval, tier_theta, set_s, set_q)
        si, qi = max(si, prev_s), max(qi, prev_q)
        points[i] = (si, qi)
        traces[i] = trace
        prev_s, prev_q = si, qi
    return points, traces


def make_schedule(si: int, qi: int, total_rounds: int,
                  set_s: Sequence[float] = DEFAULT_SET_S,
                  set_q: Sequence[int] = DEFAULT_SET_Q,
                  n_decay_steps: int = 4) -> CompressionSchedule:
    """Lines 13-18: start one notch more compressed than the static point,
    decay every ``total_rounds / n_decay_steps`` rounds."""
    s0 = min(si + 1, len(set_s) - 1)
    q0 = min(qi + 1, len(set_q) - 1)
    step = max(1, total_rounds // n_decay_steps)
    return CompressionSchedule(s0, q0, step, set_s, set_q)
