from repro.core.compression import (compress_pytree, decompress_pytree,
                                    pytree_dense_bytes, pytree_wire_bytes,
                                    roundtrip_pytree, sparsify_quantize_dense)
from repro.core.dynamic import CompressionSchedule, greedy_search, make_schedule
from repro.core.server import ServerConfig, TeasqServer
from repro.core.staleness import (aggregate_cache, merge_global, mixing_alpha,
                                  staleness_weight, weighted_average)
