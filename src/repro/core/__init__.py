from repro.core.codecs import (CODECS, Codec, DenseRefCodec, IdentityCodec,
                               PackedBitstreamCodec, ThresholdGraphCodec,
                               Wire, resolve_codec)
from repro.core.compression import (compress_pytree, decompress_pytree,
                                    expected_pytree_wire_bytes,
                                    pytree_dense_bytes, pytree_wire_bytes,
                                    roundtrip_pytree, sparsify_quantize_dense)
from repro.core.dynamic import CompressionSchedule, greedy_search, make_schedule
from repro.core.server import ServerConfig, TeasqServer
from repro.core.staleness import (aggregate_cache, merge_global, mixing_alpha,
                                  staleness_weight, weighted_average)

__all__ = [
    # codec API (the wire seam: prefer this over the raw compression fns)
    "CODECS", "Codec", "DenseRefCodec", "IdentityCodec",
    "PackedBitstreamCodec", "ThresholdGraphCodec", "Wire", "resolve_codec",
    # Algs. 3-4 primitives
    "compress_pytree", "decompress_pytree", "expected_pytree_wire_bytes",
    "pytree_dense_bytes", "pytree_wire_bytes", "roundtrip_pytree",
    "sparsify_quantize_dense",
    # Alg. 5 dynamic compression
    "CompressionSchedule", "greedy_search", "make_schedule",
    # server state machine (Algs. 1-2)
    "ServerConfig", "TeasqServer",
    "aggregate_cache", "merge_global", "mixing_alpha", "staleness_weight",
    "weighted_average",
]
