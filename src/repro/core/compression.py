"""TEASQ-Fed wire compression: Top-K sparsification + QSGD quantization.

Paper Algorithms 3 (compress) and 4 (decompress):
  1. keep the top ``p_s`` fraction of each tensor by magnitude, zero the rest;
  2. quantize the kept values to ``p_q`` bits (QSGD-style uniform levels);
  3. pack (values, indices) — zeros are not transmitted.

Two families of entry points:

* ``compress_pytree`` / ``decompress_pytree`` — the faithful packed wire
  format used by the FL protocol simulator; byte accounting matches Table 7.
* ``sparsify_quantize_dense`` — the in-graph (jit/SPMD-safe) operator used by
  ``fed_step`` on the TPU mesh: same math, dense masked layout (XLA cannot
  ship data-dependent shapes through collectives).  The Pallas kernel in
  ``repro.kernels.topk_quant`` implements the block-local TPU version.

These are the *primitives*; FL code selects between them through the
pluggable codec seam ``repro.core.codecs`` (``resolve_codec`` /
``ProtocolStrategy.channel_for``), which also hosts the real bit-packed
byte stream (``PackedBitstreamCodec``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FLOAT_BITS = 32


# ----------------------------------------------------------------------
# in-graph primitives (jit-able, used both by the simulator and fed_step)
# ----------------------------------------------------------------------
def topk_mask(x: jax.Array, p_s: float) -> jax.Array:
    """Boolean mask of the top ``p_s`` fraction of |x| (global per tensor)."""
    if p_s >= 1.0:
        return jnp.ones_like(x, bool)
    k = max(1, int(round(p_s * x.size)))
    flat = jnp.abs(x).reshape(-1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.abs(x) >= thresh


def quantize_levels(x: jax.Array, bits: int,
                    key: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """QSGD-style uniform quantization to ``bits`` bits (symmetric).

    Returns (int levels in [-L, L], scale).  With ``key`` the rounding is
    stochastic (unbiased, as in QSGD); deterministic nearest otherwise.
    """
    if bits >= FLOAT_BITS:
        return x, jnp.float32(1.0)
    L = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12).astype(jnp.float32)
    y = x.astype(jnp.float32) / scale * L
    if key is not None:
        frac = y - jnp.floor(y)
        y = jnp.floor(y) + (jax.random.uniform(key, y.shape) < frac)
    else:
        y = jnp.round(y)
    return jnp.clip(y, -L, L), scale


def dequantize_levels(levels: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    if bits >= FLOAT_BITS:
        return levels
    L = 2 ** (bits - 1) - 1
    return (levels.astype(jnp.float32) * scale / L)


def sparsify_quantize_dense(x: jax.Array, p_s: float, p_q: int,
                            key: Optional[jax.Array] = None) -> jax.Array:
    """Dense compress->decompress round trip (the in-graph lossy operator)."""
    mask = topk_mask(x, p_s)
    kept = jnp.where(mask, x, 0.0)
    levels, scale = quantize_levels(kept, p_q, key)
    return dequantize_levels(levels, scale, p_q).astype(x.dtype) * mask


def approx_topk_threshold(ax: jax.Array, p_s: float, iters: int = 12) -> jax.Array:
    """Magnitude threshold keeping ~``p_s`` of ``ax`` (= |x|), via the same
    fixed-iteration binary search the Pallas ``topk_quant`` kernel uses —
    O(iters * n) vector compares instead of an O(n log n) sort, which is what
    makes the vectorized cohort channel affordable."""
    hi0 = jnp.max(ax) + 1e-12
    lo0 = jnp.zeros((), jnp.float32)

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        keep = jnp.mean((ax >= mid).astype(jnp.float32)) > p_s
        return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    return 0.5 * (lo + hi)


def sparsify_quantize_threshold(x: jax.Array, p_s: float, p_q: int,
                                iters: int = 12) -> jax.Array:
    """Approximate in-graph channel: threshold sparsification (binary-search
    threshold, not exact Top-K) + deterministic uniform quantization.

    Same math as the Pallas kernel applied whole-tensor; the kept fraction is
    within ~2^-iters (+ magnitude ties) of ``p_s``.  Used by the vectorized
    cohort path where an exact per-device ``top_k`` would dominate runtime."""
    if p_s >= 1.0 and p_q >= FLOAT_BITS:
        return x
    xf = x.astype(jnp.float32)
    if p_s >= 1.0:
        kept = xf
        mask = jnp.ones_like(xf, bool)
    else:
        thr = approx_topk_threshold(jnp.abs(xf), p_s, iters)
        mask = jnp.abs(xf) >= thr
        kept = jnp.where(mask, xf, 0.0)
    levels, scale = quantize_levels(kept, p_q)
    return (dequantize_levels(levels, scale, p_q) * mask).astype(x.dtype)


# ----------------------------------------------------------------------
# packed wire format (protocol simulator; Alg. 3 / Alg. 4 faithful)
# ----------------------------------------------------------------------
def topk_count(n: int, p_s: float) -> int:
    """Number of kept values for an ``n``-element tensor at rate ``p_s``."""
    return max(1, int(round(p_s * n))) if p_s < 1.0 else n


def index_bits(n: int) -> int:
    """Bits per transmitted index for an ``n``-element tensor — shared by the
    analytic size model below and the actual bitstream serializer
    (``repro.core.codecs.PackedBitstreamCodec``), which must agree exactly."""
    return max(1, math.ceil(math.log2(max(n, 2))))


def _wire_bits(n: int, k: int, p_q: int) -> int:
    """Packed size of ``k`` kept values out of ``n``: p_q bits/value, index
    bits/value when sparse, one f32 scale."""
    vbits = min(p_q, FLOAT_BITS)
    return k * (vbits + (index_bits(n) if k < n else 0)) + FLOAT_BITS


def compress_tensor(x: np.ndarray, p_s: float, p_q: int,
                    rng: Optional[np.random.RandomState] = None) -> Dict[str, Any]:
    x = np.asarray(x, np.float32)
    flat = x.reshape(-1)
    n = flat.size
    k = topk_count(n, p_s)
    if k < n:
        ax = np.abs(flat)
        idx = np.argpartition(ax, n - k)[n - k:]
        # argpartition's choice among magnitudes tied at the k-th place is
        # arbitrary; the wire format pins the canonical rule "boundary ties
        # keep the smallest flat indices" (WIRE_FORMAT.md) so the fused
        # kernel/host emitters in repro.kernels.fused_pack agree with this
        # oracle bit-for-bit.  Only the ambiguous slots are rewritten: when
        # every tied magnitude is already selected (the common case), idx —
        # and hence the stochastic-rounding RNG draw order — is untouched.
        kth_sel = ax[idx] == ax[idx].min()
        canon = np.flatnonzero(ax == ax[idx].min())
        if canon.size > int(np.count_nonzero(kth_sel)):
            idx = idx.copy()
            idx[kth_sel] = canon[:int(np.count_nonzero(kth_sel))]
    else:
        idx = np.arange(n)
    values = flat[idx]
    if p_q < FLOAT_BITS:
        L = 2 ** (p_q - 1) - 1
        scale = max(float(np.max(np.abs(values))), 1e-12)
        y = values / scale * L
        if rng is not None:
            y = np.floor(y) + (rng.random_sample(y.shape) < (y - np.floor(y)))
        else:
            y = np.round(y)
        values = np.clip(y, -L, L).astype(np.int32)
    else:
        scale = 1.0
    return {"values": values, "indices": idx.astype(np.int64),
            "scale": scale, "shape": x.shape, "p_q": p_q, "n": n}


def decompress_tensor(c: Dict[str, Any]) -> np.ndarray:
    flat = np.zeros(c["n"], np.float32)
    vals = c["values"]
    if c["p_q"] < FLOAT_BITS:
        L = 2 ** (c["p_q"] - 1) - 1
        vals = vals.astype(np.float32) * c["scale"] / L
    flat[c["indices"]] = vals
    return flat.reshape(c["shape"])


def tensor_wire_bits(c: Dict[str, Any],
                     index_bits_override: Optional[int] = None) -> int:
    """Transmitted size: p_q bits/value + index bits/value + one f32 scale."""
    k = len(c["values"])
    if index_bits_override is not None:
        vbits = min(c["p_q"], FLOAT_BITS)
        return k * (vbits + (index_bits_override if k < c["n"] else 0)) \
            + FLOAT_BITS
    return _wire_bits(c["n"], k, c["p_q"])


def compress_pytree(tree: Any, p_s: float, p_q: int,
                    rng: Optional[np.random.RandomState] = None) -> Any:
    return jax.tree.map(lambda x: compress_tensor(np.asarray(x), p_s, p_q, rng), tree)


def decompress_pytree(ctree: Any) -> Any:
    return jax.tree.map(decompress_tensor, ctree,
                        is_leaf=lambda x: isinstance(x, dict) and "values" in x)


def pytree_wire_bytes(ctree: Any) -> int:
    """Transmitted size of a compressed pytree: one bit-level concatenated
    stream across tensors (no per-tensor byte alignment), rounded up to whole
    bytes — exactly what ``repro.core.codecs.PackedBitstreamCodec`` emits."""
    leaves = jax.tree.leaves(
        ctree, is_leaf=lambda x: isinstance(x, dict) and "values" in x)
    return (sum(tensor_wire_bits(c) for c in leaves) + 7) // 8


def pytree_dense_bytes(tree: Any) -> int:
    return sum(x.size * 4 for x in jax.tree.leaves(tree))


def expected_tensor_wire_bits(n: int, p_s: float, p_q: int) -> int:
    """Wire size of an ``n``-element tensor under (p_s, p_q) — identical to
    ``tensor_wire_bits`` after an actual compression, but computed from shape
    alone (the packed format's size is value-independent).  Lets the deferred
    cohort path schedule arrivals before training has produced the update."""
    return _wire_bits(n, topk_count(n, p_s), p_q)


def expected_pytree_wire_bytes(tree: Any, p_s: float, p_q: int) -> int:
    """Shape-only ``pytree_wire_bytes`` (matches the dense-bytes fast path of
    the simulator channel when no compression is active)."""
    if p_s >= 1.0 and p_q >= FLOAT_BITS:
        return pytree_dense_bytes(tree)
    return (sum(expected_tensor_wire_bits(x.size, p_s, p_q)
                for x in jax.tree.leaves(tree)) + 7) // 8


def roundtrip_pytree(tree: Any, p_s: float, p_q: int,
                     rng: Optional[np.random.RandomState] = None
                     ) -> Tuple[Any, int]:
    """compress -> wire bytes -> decompress (the lossy channel)."""
    c = compress_pytree(tree, p_s, p_q, rng)
    return decompress_pytree(c), pytree_wire_bytes(c)
