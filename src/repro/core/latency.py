"""Latency model (paper §3.1 + §5.1 wireless setup).

Communication: devices uniform in a disk of radius R around the base
station; max rate r = B log2(1 + P h^2 / (B N0)) with path-loss exponent
alpha_pl.  Computation: shifted exponential (Eq. 2):
  P[L < l] = 1 - exp(-(phi_k / (tau b)) (l - a_k tau b)),  l >= a_k tau b.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class WirelessConfig:
    radius_m: float = 600.0
    # per-device OFDMA share: ~10 concurrent devices split the 20 MHz cell
    # (the paper's C-fraction keeps ~N*C=10 devices transmitting)
    bandwidth_hz: float = 2e6
    pathloss_exp: float = 3.76
    p_server_dbm: float = 20.0
    p_device_dbm: float = 10.0
    noise_dbm_per_mhz: float = -114.0


def _dbm_to_w(dbm: float) -> float:
    return 10 ** (dbm / 10.0) / 1000.0


def device_rates(n_devices: int, cfg: WirelessConfig,
                 rng: np.random.RandomState):
    """Sample device positions; return (down_rates, up_rates) in bits/s."""
    # uniform in disk
    r = cfg.radius_m * np.sqrt(rng.random_sample(n_devices))
    d = np.maximum(r, 1.0)
    gain = d ** (-cfg.pathloss_exp)                  # h^2 (path loss only)
    n0_w = _dbm_to_w(cfg.noise_dbm_per_mhz) * (cfg.bandwidth_hz / 1e6)
    snr_down = _dbm_to_w(cfg.p_server_dbm) * gain / n0_w
    snr_up = _dbm_to_w(cfg.p_device_dbm) * gain / n0_w
    down = cfg.bandwidth_hz * np.log2(1.0 + snr_down)
    up = cfg.bandwidth_hz * np.log2(1.0 + snr_up)
    return down, up


@dataclasses.dataclass
class ComputeConfig:
    """Shifted-exponential per-device compute latency (Eq. 2)."""
    a_min: float = 0.3      # per-unit-work shift coefficient range
    a_max: float = 2.0      # (heterogeneous device speeds, ~6x spread)
    phi: float = 3.0        # fluctuation (higher = less noise)


def sample_compute_latency(a_k: float, phi_k: float, tau_b: float,
                           rng: np.random.RandomState) -> float:
    """One draw of L^cp: shift a_k*tau_b plus Exp(phi_k / tau_b)."""
    shift = a_k * tau_b
    return shift + rng.exponential(tau_b / phi_k)


def comm_latency(bits: float, rate_bps: float) -> float:
    return bits / max(rate_bps, 1.0)


# ----------------------------------------------------------------------
# Vectorized (wave) variants.
#
# RNG draw-order contract: ``sample_compute_latency_batch`` consumes the
# generator with ONE ``rng.exponential(size=G)`` call, i.e. exactly the
# stream positions G sequential scalar draws would use, with value i
# going to position i of the input arrays.  Wave callers pass the arrays
# in ascending device-index order (the documented relaxed-parity order of
# ``SimConfig.handler_mode="wave"``), so draw i belongs to the i-th
# lowest device id of the wave — not to the i-th heap pop.
# ----------------------------------------------------------------------
def comm_latency_batch(bits, rate_bps: np.ndarray) -> np.ndarray:
    """Elementwise ``comm_latency`` — same float64 ops, no RNG."""
    return np.asarray(bits, dtype=np.float64) / np.maximum(rate_bps, 1.0)


def sample_compute_latency_batch(a_k: np.ndarray, phi_k: np.ndarray,
                                 tau_b: np.ndarray,
                                 rng: np.random.RandomState) -> np.ndarray:
    """G draws of L^cp in one call (see draw-order contract above)."""
    tau_b = np.asarray(tau_b, dtype=np.float64)
    return a_k * tau_b + rng.exponential(tau_b / phi_k)
