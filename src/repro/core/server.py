"""Server-side TEASQ-Fed state machine (paper Algs. 1-2, server process).

Distributor: admission-controls task requests with the C-fraction gate.
Receiver/Updater: caches K = ceil(N*gamma) updates, then performs the
staleness-weighted aggregation of Eqs. 6-10.

``SERVERS`` registers the available server backends (the same
one-subclass-plus-one-entry idiom as STRATEGIES / CODECS / SCHEDULERS):

* ``"single"`` — :class:`TeasqServer`, the bit-pinned single-host
  reference every history fixture was recorded against.
* ``"sharded"`` — :class:`ShardedTeasqServer`, which partitions the
  flattened weight vector across a 1-D device mesh (host devices under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and runs the
  stacked Eqs. 6-10 reduction as a ``shard_map``; with one device it
  degenerates to the parent's exact path.

``SimConfig.server`` selects the backend; ``make_server`` resolves it.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.staleness import (aggregate_cache, aggregate_cache_stacked,
                                  make_sharded_aggregator)


@dataclasses.dataclass
class ServerConfig:
    n_devices: int
    c_fraction: float = 0.1     # C: max fraction of devices training in parallel
    gamma: float = 0.1          # cache fraction: K = ceil(N * gamma)
    alpha: float = 0.6          # mixing hyper-parameter (Eq. 9)
    a: float = 0.5              # staleness exponent (Eq. 6)

    # cached: the admission gate reads these on every event-loop iteration
    @functools.cached_property
    def max_parallel(self) -> int:
        return max(1, math.ceil(self.n_devices * self.c_fraction))

    @functools.cached_property
    def cache_size(self) -> int:
        return max(1, math.ceil(self.n_devices * self.gamma))


class TeasqServer:
    """Holds the global model, round counter t, active count P and cache Q."""

    def __init__(self, w_init: Any, cfg: ServerConfig):
        self.cfg = cfg
        self.w = w_init
        self.t = 0
        self.active = 0                      # P
        self.cache: List[Tuple[Any, int, int]] = []   # (w_local, h_c, n_c)

    # -- Distributor (Alg. 1 server) ------------------------------------
    def try_dispatch(self) -> Optional[Tuple[Any, int]]:
        """Admit a task request: returns (w^t, t) or None if P >= ceil(N*C)."""
        if self.active >= self.cfg.max_parallel:
            return None
        self.active += 1
        return self.w, self.t

    # -- Receiver + Updater (Alg. 2) ------------------------------------
    def _aggregate(self) -> Any:
        """Eqs. 6-10 over the full cache via the serial K-tuple kernel —
        the bit-pinned reference path; subclasses may re-route."""
        return aggregate_cache(self.w, self.cache, self.t,
                               self.cfg.alpha, self.cfg.a)

    def _aggregate_stacked(self) -> Any:
        """Eqs. 6-10 via the stacked leading-axis kernel (wave mode's
        relaxed-parity path); subclasses may re-route."""
        return aggregate_cache_stacked(self.w, self.cache, self.t,
                                       self.cfg.alpha, self.cfg.a)

    def receive(self, w_local: Any, h: int, n_samples: int) -> bool:
        """Push an update; aggregate when the cache reaches K.
        Returns True if an aggregation round completed."""
        self.active = max(0, self.active - 1)
        self.cache.append((w_local, h, n_samples))
        if len(self.cache) < self.cfg.cache_size:
            return False
        self.w = self._aggregate()
        self.cache.clear()
        self.t += 1
        return True

    def receive_many(self, entries: List[Tuple[Any, int, int]]) -> List[bool]:
        """Wave-mode Receiver (Alg. 2 over a whole arrival group): push the
        group's ``(w_local, h_c, n_c)`` entries in event order, aggregating
        at every cache-fill boundary with the *stacked* Eqs. 6-10 kernel
        (``aggregate_cache_stacked`` — one leading-axis stack per leaf
        instead of K separate tree arguments).  Same cache/round semantics
        as K calls to :meth:`receive`; the reduction order inside one
        aggregation differs (tensordot vs. sequential sum), which is part of
        ``handler_mode="wave"``'s relaxed-parity contract."""
        done = []
        for w_local, h, n_samples in entries:
            self.active = max(0, self.active - 1)
            self.cache.append((w_local, h, n_samples))
            if len(self.cache) < self.cfg.cache_size:
                done.append(False)
                continue
            self.w = self._aggregate_stacked()
            self.cache.clear()
            self.t += 1
            done.append(True)
        return done


class ShardedTeasqServer(TeasqServer):
    """`TeasqServer` with the Eqs. 6-10 reduction sharded over a device
    mesh (the "Sharded aggregation" ROADMAP tentpole).

    The flattened weight vector is partitioned into equal column blocks
    across a 1-D mesh of the first ``n_shards`` local jax devices (host
    devices when the process runs under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``), and both the
    serial and the wave receive paths reduce through ONE
    ``shard_map``-compiled flat kernel (``make_sharded_aggregator``).
    Every shard computes the identical per-element program as the
    single-host stacked kernel, so the sharded weights match
    ``aggregate_cache_stacked`` to <= 1 ulp (tests/test_sharded_server.py
    pins this across mesh sizes).

    With ``n_shards`` resolving to 1 (the default single-device process)
    no mesh is built and BOTH paths delegate to the parent's kernels
    unchanged — the degenerate server is bit-identical to
    :class:`TeasqServer`, so the pinned history fixtures stay valid under
    ``SimConfig.server="sharded"`` on one device."""

    def __init__(self, w_init: Any, cfg: ServerConfig, n_shards: int = 0):
        super().__init__(w_init, cfg)
        import jax
        import numpy as np
        from jax.sharding import Mesh
        devs = jax.devices()
        want = int(n_shards) if n_shards > 0 else len(devs)
        self.n_shards = max(1, min(want, len(devs)))
        self.mesh = None
        self._agg = None
        if self.n_shards > 1:
            self.mesh = Mesh(np.asarray(devs[:self.n_shards]), ("agg",))
            self._agg = make_sharded_aggregator(self.mesh)

    def _aggregate(self) -> Any:
        if self._agg is None:      # degenerate mesh: exact parent path
            return super()._aggregate()
        return self._agg(self.w, self.cache, self.t,
                         self.cfg.alpha, self.cfg.a)

    # one flat sharded kernel serves both receive paths: the stacked and
    # the serial single-host kernels only differ in reduction order, and
    # the sharded reduction already follows the stacked one
    _aggregate_stacked = _aggregate


# server registry: SimConfig.server -> class (the same
# one-subclass-plus-one-entry idiom as STRATEGIES / CODECS / SCHEDULERS)
SERVERS: Dict[str, type] = {
    "single": TeasqServer,
    "sharded": ShardedTeasqServer,
}


def make_server(name: str, w_init: Any, cfg: ServerConfig, *,
                shards: int = 0) -> TeasqServer:
    """Resolve ``SimConfig.server`` to a constructed server backend.
    ``shards`` (``SimConfig.server_shards``) caps the mesh width for
    sharded backends: 0 means "all local devices"."""
    try:
        cls = SERVERS[name]
    except KeyError:
        raise ValueError(f"unknown server {name!r}; "
                         f"expected one of {sorted(SERVERS)}") from None
    if issubclass(cls, ShardedTeasqServer):
        return cls(w_init, cfg, n_shards=shards)
    return cls(w_init, cfg)
