"""Server-side TEASQ-Fed state machine (paper Algs. 1-2, server process).

Distributor: admission-controls task requests with the C-fraction gate.
Receiver/Updater: caches K = ceil(N*gamma) updates, then performs the
staleness-weighted aggregation of Eqs. 6-10.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, List, Optional, Tuple

from repro.core.staleness import aggregate_cache, aggregate_cache_stacked


@dataclasses.dataclass
class ServerConfig:
    n_devices: int
    c_fraction: float = 0.1     # C: max fraction of devices training in parallel
    gamma: float = 0.1          # cache fraction: K = ceil(N * gamma)
    alpha: float = 0.6          # mixing hyper-parameter (Eq. 9)
    a: float = 0.5              # staleness exponent (Eq. 6)

    # cached: the admission gate reads these on every event-loop iteration
    @functools.cached_property
    def max_parallel(self) -> int:
        return max(1, math.ceil(self.n_devices * self.c_fraction))

    @functools.cached_property
    def cache_size(self) -> int:
        return max(1, math.ceil(self.n_devices * self.gamma))


class TeasqServer:
    """Holds the global model, round counter t, active count P and cache Q."""

    def __init__(self, w_init: Any, cfg: ServerConfig):
        self.cfg = cfg
        self.w = w_init
        self.t = 0
        self.active = 0                      # P
        self.cache: List[Tuple[Any, int, int]] = []   # (w_local, h_c, n_c)

    # -- Distributor (Alg. 1 server) ------------------------------------
    def try_dispatch(self) -> Optional[Tuple[Any, int]]:
        """Admit a task request: returns (w^t, t) or None if P >= ceil(N*C)."""
        if self.active >= self.cfg.max_parallel:
            return None
        self.active += 1
        return self.w, self.t

    # -- Receiver + Updater (Alg. 2) ------------------------------------
    def receive(self, w_local: Any, h: int, n_samples: int) -> bool:
        """Push an update; aggregate when the cache reaches K.
        Returns True if an aggregation round completed."""
        self.active = max(0, self.active - 1)
        self.cache.append((w_local, h, n_samples))
        if len(self.cache) < self.cfg.cache_size:
            return False
        self.w = aggregate_cache(self.w, self.cache, self.t,
                                 self.cfg.alpha, self.cfg.a)
        self.cache.clear()
        self.t += 1
        return True

    def receive_many(self, entries: List[Tuple[Any, int, int]]) -> List[bool]:
        """Wave-mode Receiver (Alg. 2 over a whole arrival group): push the
        group's ``(w_local, h_c, n_c)`` entries in event order, aggregating
        at every cache-fill boundary with the *stacked* Eqs. 6-10 kernel
        (``aggregate_cache_stacked`` — one leading-axis stack per leaf
        instead of K separate tree arguments).  Same cache/round semantics
        as K calls to :meth:`receive`; the reduction order inside one
        aggregation differs (tensordot vs. sequential sum), which is part of
        ``handler_mode="wave"``'s relaxed-parity contract."""
        done = []
        for w_local, h, n_samples in entries:
            self.active = max(0, self.active - 1)
            self.cache.append((w_local, h, n_samples))
            if len(self.cache) < self.cfg.cache_size:
                done.append(False)
                continue
            self.w = aggregate_cache_stacked(self.w, self.cache, self.t,
                                             self.cfg.alpha, self.cfg.a)
            self.cache.clear()
            self.t += 1
            done.append(True)
        return done
