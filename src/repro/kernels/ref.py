"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# topk_quant oracle: block-local Top-K (threshold) + symmetric int quant
# ----------------------------------------------------------------------
def topk_quant_ref(x: jax.Array, p_s: float, bits: int,
                   iters: int = 16) -> Tuple[jax.Array, jax.Array]:
    """x: (M, B) blocks -> (levels int8 (M,B), scales f32 (M,1)).

    Per block: binary-search the magnitude threshold keeping ~p_s of entries
    (the TPU-native sort-free Top-K), then quantize kept values to ``bits``
    bits with a per-block max-abs scale.
    """
    ax = jnp.abs(x.astype(jnp.float32))

    def per_block(axb, xb):
        hi0 = jnp.max(axb) + 1e-12
        lo0 = jnp.zeros((), jnp.float32)

        def body(_, lh):
            lo, hi = lh
            mid = 0.5 * (lo + hi)
            frac = jnp.mean((axb >= mid).astype(jnp.float32))
            keep = frac > p_s
            return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

        lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
        thr = 0.5 * (lo + hi)
        mask = axb >= thr
        kept = jnp.where(mask, xb.astype(jnp.float32), 0.0)
        L = 2 ** (bits - 1) - 1
        scale = jnp.maximum(jnp.max(jnp.abs(kept)), 1e-12)
        levels = jnp.clip(jnp.round(kept / scale * L), -L, L).astype(jnp.int8)
        return levels, scale

    levels, scales = jax.vmap(per_block)(ax, x)
    return levels, scales[:, None]


def dequant_ref(levels: jax.Array, scales: jax.Array, bits: int) -> jax.Array:
    L = 2 ** (bits - 1) - 1
    return levels.astype(jnp.float32) * scales / L


# ----------------------------------------------------------------------
# SSD intra-chunk oracle (one chunk, one head)
# ----------------------------------------------------------------------
def ssd_chunk_ref(xb: jax.Array, b: jax.Array, c: jax.Array, cum: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One chunk of SSD.
    xb: (L,P) dt-scaled inputs; b,c: (L,N); cum: (L,) cumulative log decay.
    Returns (y_intra (L,P), state (N,P), chunk_decay scalar exp(cum[-1]))."""
    xb = xb.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    L_ = xb.shape[0]
    cb = c @ b.T                                      # (L,L)
    mask = jnp.tril(jnp.ones((L_, L_), bool))
    diff = jnp.where(mask, cum[:, None] - cum[None, :], -jnp.inf)
    m = jnp.exp(diff)
    y = (cb * m) @ xb                                 # (L,P)
    decay_to_end = jnp.exp(cum[-1] - cum)             # (L,)
    state = (b * decay_to_end[:, None]).T @ xb        # (N,P)
    return y, state, jnp.exp(cum[-1])


def ssd_full_ref(xh, b, c, dt, la, chunk: int):
    """Full-sequence oracle — delegates to the model's chunked implementation
    (itself validated against one-token recurrence in tests)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(xh, b, c, dt, la, chunk)
