"""Fused Pallas kernel: sparsify + quantize + bit-pack in ONE pass (Alg. 3).

The packed wire encode used to be a multi-pass host pipeline (per-leaf
``compress_tensor`` -> argsort -> delta-code -> ``pack_segments``), making
the paper's headline codec the slowest path in the stack.  This module fuses
the whole of Algorithm 3 into a single kernel that writes the packed uint32
stream words directly:

1. **exact Top-K selection** — a fixed-iteration (31-step) greedy binary
   search over the uint32 bit patterns of ``|x|`` (IEEE-754 non-negative
   floats order like unsigned ints, the trick behind the fixed-iteration
   search in ``topk_quant``; here run to completion so the threshold is the
   *exact* k-th largest magnitude, not an approximation).  Ties at the
   threshold keep the smallest flat indices — the canonical rule shared
   with ``repro.core.compression.compress_tensor`` (WIRE_FORMAT.md,
   "Determinism").
2. **quantize** — offset-binary QSGD levels ``round(x / scale * L) + L``
   (deterministic nearest-even rounding; f32 max-abs scale over survivors),
   or raw f32 bit patterns at ``p_q >= 32``.
3. **pack** — survivor ranks from an exclusive prefix sum over the keep
   mask give every field its absolute bit offset in the stream
   (``32 + rank*vbits`` for values, ``32 + k*vbits + rank*ibits`` for the
   delta-coded indices, scale at bit 0); each field spans at most two
   big-endian uint32 words, emitted with a shift/OR scatter-add (bit-
   disjoint contributions, so integer add == bitwise OR).  Deltas come from
   ``cummax`` over survivor positions — no sort, no gather/compaction.

The emitted stream is **bit-identical** to ``PackedBitstreamCodec``'s host
pipeline (docs/WIRE_FORMAT.md stays normative) and ``len(bytes) ==
expected_pytree_wire_bytes`` exactly.

Three executions of the same math:

* ``fused_pack_leaf(..., interpret=True)`` — the Pallas kernel body run by
  the interpreter (bit-accurate, CPU CI);
* ``fused_pack_leaf(..., interpret=False)`` — native TPU lowering
  (``REPRO_PALLAS_NATIVE=1`` via ``repro.kernels.ops``);
* ``pack_leaves_host`` — a vectorized numpy twin (partition + one word-level
  ``pack_segments`` pass).  On CPU the twin IS the production path: per-leaf
  pallas_call dispatch costs ~ms on host, same reason ``bitpack`` keeps
  numpy twins of its jnp kernels.

All quantization arithmetic is f32 in the same operation order
(``(x / scale) * L``) in all three, so they agree bit-for-bit; the host
oracle ``compress_tensor`` computes the identical f32 expression (numpy
keeps f32 for array-op-python-scalar), pinned by tests/test_fused_pack.

VMEM note: the kernel holds one whole (padded) leaf plus its output words
in VMEM — fine for this repo's models (largest leaf 200,704 f32 = 0.8 MB;
VMEM ~16 MB/core, comfortable to ~2M elements).  Larger leaves would need a
grid-blocked variant with per-block survivor-count prefix sums; the host
twin has no such limit.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.compression import (FLOAT_BITS, expected_tensor_wire_bits,
                                    index_bits, topk_count)
from repro.kernels.bitpack import pack_segments, words_to_bytes

_LANES = 128                   # TPU lane width; pad shapes to multiples


# ----------------------------------------------------------------------
# Pallas kernel
# ----------------------------------------------------------------------
def _scatter_field(words: jax.Array, vals: jax.Array, offsets: jax.Array,
                   width: int) -> jax.Array:
    """OR ``width``-bit fields into the (1, nw) uint32 word vector.

    ``vals`` must already be zero for dead lanes (their offsets may then
    point anywhere in range — adding zero is a no-op; out-of-range lanes
    are dropped by the scatter mode).  In-word shift ``32 - off%32 - width``
    < 0 means the field straddles into the next word.
    """
    w = offsets >> 5
    sh = 32 - (offsets & 31) - width
    hi = jnp.left_shift(jnp.right_shift(vals, jnp.maximum(-sh, 0).astype(jnp.uint32)),
                        jnp.maximum(sh, 0).astype(jnp.uint32))
    lo = jnp.where(sh < 0,
                   jnp.left_shift(vals, jnp.clip(sh + 32, 0, 31).astype(jnp.uint32)),
                   jnp.uint32(0))
    words = words.at[0, w].add(hi, mode="drop")
    words = words.at[0, w + 1].add(lo, mode="drop")
    return words


def _fused_kernel(x_ref, words_ref, *, n: int, k: int, p_q: int):
    x = x_ref[0, :]                                     # (npad,) f32
    idx = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], 1), 0)[:, 0]
    valid = idx < n
    ax = jnp.abs(x)
    # uint32 patterns of |x| order like unsigned ints (IEEE-754, x >= 0)
    bits = jnp.where(valid, jax.lax.bitcast_convert_type(ax, jnp.uint32),
                     jnp.uint32(0))

    if k < n:
        # exact k-th largest magnitude: greedily set pattern bits MSB->LSB,
        # keeping a bit iff >= k magnitudes still clear the candidate.
        # 31 iterations (sign bit of |x| is 0); T ends as the exact pattern.
        def step(i, t):
            cand = t | jnp.left_shift(jnp.uint32(1),
                                      (30 - i).astype(jnp.uint32))
            cnt = jnp.sum((bits >= cand).astype(jnp.int32))
            return jnp.where(cnt >= k, cand, t)

        thr = jax.lax.fori_loop(0, 31, step, jnp.uint32(0))
        above = bits > thr
        g = jnp.sum(above.astype(jnp.int32))
        tie = valid & (bits == thr)
        tie_rank = jnp.cumsum(tie.astype(jnp.int32)) - tie.astype(jnp.int32)
        mask = above | (tie & (tie_rank < (k - g)))     # smallest-index ties
    else:
        mask = valid
    mf = mask.astype(jnp.uint32)

    vbits = min(p_q, FLOAT_BITS)
    if p_q < FLOAT_BITS:
        L = 2 ** (p_q - 1) - 1
        scale = jnp.maximum(jnp.max(jnp.where(mask, ax, 0.0)), 1e-12)
        levels = jnp.clip(jnp.round((x / scale) * L), -L, L).astype(jnp.int32)
        field = (levels + L).astype(jnp.uint32) * mf
    else:
        scale = jnp.float32(1.0)
        field = jax.lax.bitcast_convert_type(x, jnp.uint32) * mf

    # survivor rank = exclusive prefix sum of the keep mask -> bit offsets
    rank = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    words = jnp.zeros(words_ref.shape, jnp.uint32)
    words = words.at[0, 0].set(jax.lax.bitcast_convert_type(scale, jnp.uint32))
    words = _scatter_field(words, field, FLOAT_BITS + rank * vbits, vbits)
    if k < n:
        # delta-coded survivor indices without a sort: the previous
        # survivor's position is the running max of masked iota, shifted by
        # one lane (first survivor's "previous" is 0, so its delta is its
        # absolute index — matching the host serializer's deltas[0]).
        pm = jax.lax.cummax(jnp.where(mask, idx, 0), axis=0)
        prev = jnp.where(idx == 0, 0, jnp.roll(pm, 1))
        delta = (idx - prev).astype(jnp.uint32) * mf
        words = _scatter_field(words, delta,
                               FLOAT_BITS + k * vbits + rank * index_bits(n),
                               index_bits(n))
    words_ref[...] = words


@functools.partial(jax.jit,
                   static_argnames=("n", "k", "p_q", "nw_pad", "interpret"))
def _fused_pack_call(xp: jax.Array, n: int, k: int, p_q: int, nw_pad: int,
                     interpret: bool) -> jax.Array:
    kern = functools.partial(_fused_kernel, n=n, k=k, p_q=p_q)
    words = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, xp.shape[1]), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, nw_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nw_pad), jnp.uint32),
        interpret=interpret,
    )(xp)
    return words[0]


def fused_pack_leaf(x, p_s: float, p_q: int,
                    interpret: bool = True) -> Tuple[bytes, int]:
    """Kernel-encode ONE tensor -> (its packed wire segment, its bit length).

    The returned bytes are the tensor's stream slice zero-padded to a whole
    byte; ``concat_bitstreams`` re-joins slices at bit granularity.
    """
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    n = int(flat.size)
    k = topk_count(n, p_s)
    nbits = expected_tensor_wire_bits(n, p_s, p_q)
    npad = max(_LANES, -(-n // _LANES) * _LANES)
    nw_pad = max(_LANES, -(-((nbits + 31) // 32) // _LANES) * _LANES)
    xp = jnp.zeros((1, npad), jnp.float32).at[0, :n].set(flat)
    words = _fused_pack_call(xp, n, k, int(p_q), nw_pad, interpret)
    return words_to_bytes(np.asarray(words), nbits), nbits


def pack_leaves_pallas(leaves: Sequence, p_s: float, p_q: int,
                       interpret: bool = True) -> bytes:
    """Whole-pytree fused encode through the Pallas kernel."""
    return concat_bitstreams([fused_pack_leaf(x, p_s, p_q, interpret)
                              for x in leaves])


# ----------------------------------------------------------------------
# numpy twin (the production CPU path)
# ----------------------------------------------------------------------
def _select_topk_idx(flat: np.ndarray, k: int) -> np.ndarray:
    """Sorted flat indices of the ``k`` largest ``|flat|``; boundary ties
    keep the smallest flat indices (the canonical rule, WIRE_FORMAT.md).

    Selection runs on the uint32 bit patterns of ``|x|`` (non-negative
    IEEE-754 floats order like unsigned ints — the same trick the Pallas
    kernel's binary search uses): integer introselect is measurably faster
    than f32, and ``argpartition`` hands back the survivor indices
    directly, skipping the full-length boolean compaction
    (``np.flatnonzero`` over ``n`` elements) that dominated the mask-based
    route.  ``argpartition``'s pick among tied magnitudes is arbitrary, so
    an ambiguous boundary (selected tie count != total tie count) falls
    back to the canonical strictly-greater + smallest-index-ties path.
    """
    n = flat.size
    b = flat.view(np.uint32) & np.uint32(0x7FFFFFFF)
    ip = np.argpartition(b, n - k)
    kth = b[ip[n - k]]
    sel = ip[n - k:]
    if np.count_nonzero(b[sel] == kth) != np.count_nonzero(b == kth):
        mask = b > kth
        t = k - int(np.count_nonzero(mask))
        mask[np.flatnonzero(b == kth)[:t]] = True
        return np.flatnonzero(mask)
    return np.sort(sel.astype(np.int32))


def pack_leaves_host(leaves: Sequence, p_s: float, p_q: int) -> bytes:
    """Vectorized numpy twin of the fused kernel: partition-select, quantize,
    delta-code, then ONE word-level ``pack_segments`` pass for all leaves.

    Bit-identical to both the Pallas kernel and the ``compress_tensor`` ->
    ``PackedBitstreamCodec._tensor_segments`` oracle pipeline (deterministic
    rounding): the quantizer is the same f32 expression ``(v / scale) * L``
    with round-half-even, and selection uses the same canonical tie rule.
    """
    vbits = min(p_q, FLOAT_BITS)
    segs: List[Tuple[np.ndarray, int]] = []
    for x in leaves:
        flat = np.asarray(x, np.float32).reshape(-1)
        n = flat.size
        k = topk_count(n, p_s)
        if k < n:
            idx = _select_topk_idx(flat, k)     # index-sorted
            vals = flat[idx]
        else:
            idx = None
            vals = flat
        if p_q < FLOAT_BITS:
            L = 2 ** (p_q - 1) - 1
            scale = max(float(np.max(np.abs(vals))), 1e-12)
            y = np.clip(np.round(vals / scale * L), -L, L)
            u_vals = (y.astype(np.int64) + L).astype(np.uint32)
        else:
            scale = 1.0
            u_vals = vals.astype(np.float32).view(np.uint32)
        segs.append((np.asarray(scale, np.float32).reshape(1).view(np.uint32),
                     FLOAT_BITS))
        segs.append((u_vals, vbits))
        if idx is not None:
            deltas = np.empty(k, np.int64)
            deltas[0] = idx[0]
            np.subtract(idx[1:], idx[:-1], out=deltas[1:])
            segs.append((deltas.astype(np.uint32), index_bits(n)))
    return pack_segments(segs)


# ----------------------------------------------------------------------
# bit-level stream concatenation
# ----------------------------------------------------------------------
def concat_bitstreams(parts: Sequence[Tuple[bytes, int]]) -> bytes:
    """Join per-tensor (payload, nbits) slices into one bit-level stream.

    Each payload's bits past its ``nbits`` must be zero (true for
    ``fused_pack_leaf`` / ``pack_segments`` output).  A slice lands at an
    arbitrary bit offset, so each of its words contributes to two output
    words; both contributions come from one uint64 shift and the output
    accumulates with |=.
    """
    total = sum(nb for _, nb in parts)
    if total == 0:
        return b""
    nw = (total + 31) // 32
    out = np.zeros(nw + 1, np.uint64)
    pos = 0
    for payload, nbits in parts:
        if nbits == 0:
            continue
        pad = (-len(payload)) % 4
        w = np.frombuffer(payload + b"\x00" * pad, dtype=">u4").astype(np.uint64)
        base, s = pos >> 5, pos & 31
        comb = w << np.uint64(32 - s)        # s=0 -> shift 32, still < 64
        out[base:base + w.size] |= comb >> np.uint64(32)
        out[base + 1:base + 1 + w.size] |= comb & np.uint64(0xFFFFFFFF)
        pos += nbits
    return words_to_bytes(out[:nw], total)
