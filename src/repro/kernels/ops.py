"""Public jit'd wrappers for the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only, so the
kernels execute their bodies in Python (bit-accurate) while targeting TPU
``pallas_call`` + BlockSpec lowering.  On real TPU hardware pass
``interpret=False`` (or set REPRO_PALLAS_NATIVE=1).
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ssd_scan import ssd_chunked_pallas
from repro.kernels.topk_quant import DEFAULT_BLOCK, dequant, topk_quant

_NATIVE = bool(int(os.environ.get("REPRO_PALLAS_NATIVE", "0")))


def compress_roundtrip(x: jax.Array, p_s: float = 0.25, bits: int = 8,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool = None) -> jax.Array:
    """Kernel-backed lossy compress->decompress of an arbitrary tensor."""
    if interpret is None:
        interpret = not _NATIVE
    levels, scales = topk_quant(x.reshape(-1), p_s=p_s, bits=bits,
                                block=block, interpret=interpret)
    return dequant(levels, scales, bits, x.size, x.shape).astype(x.dtype)


def ssd(xh, b, c, dt, la, chunk: int, use_pallas: bool = True,
        interpret: bool = None):
    """Mamba2 SSD: kernel-backed or pure-jnp reference."""
    if interpret is None:
        interpret = not _NATIVE
    if use_pallas:
        return ssd_chunked_pallas(xh, b, c, dt, la, chunk,
                                  interpret=interpret)
    return ref.ssd_full_ref(xh, b, c, dt, la, chunk)
