"""Public jit'd wrappers for the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only, so the
kernels execute their bodies in Python (bit-accurate) while targeting TPU
``pallas_call`` + BlockSpec lowering.  On real TPU hardware pass
``interpret=False`` (or set REPRO_PALLAS_NATIVE=1).
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import fused_pack, ref
from repro.kernels.ssd_scan import ssd_chunked_pallas
from repro.kernels.topk_quant import DEFAULT_BLOCK, dequant, topk_quant

_NATIVE = bool(int(os.environ.get("REPRO_PALLAS_NATIVE", "0")))


def fused_wire_encode(tree: Any, p_s: float, p_q: int,
                      backend: Optional[str] = None) -> bytes:
    """One-pass packed wire encode of a pytree (Alg. 3 serialization).

    Bit-identical to ``PackedBitstreamCodec``'s host oracle pipeline with
    deterministic rounding; ``len(result) == expected_pytree_wire_bytes``.

    ``backend``:
      * ``None`` — auto: the native Pallas kernel when REPRO_PALLAS_NATIVE=1
        (real TPU), otherwise the vectorized numpy twin (on CPU the twin is
        the fast path — per-leaf pallas_call dispatch costs ~ms on host,
        the same trade ``bitpack`` makes for its jnp kernels);
      * ``"host"`` — force the numpy twin;
      * ``"interpret"`` — force the Pallas kernel under the interpreter
        (bit-accurate kernel body on CPU; what CI exercises);
      * ``"native"`` — force real TPU lowering.
    """
    if backend is None:
        backend = "native" if _NATIVE else "host"
    leaves = jax.tree.leaves(tree)
    if backend == "host":
        return fused_pack.pack_leaves_host(leaves, p_s, p_q)
    if backend not in ("interpret", "native"):
        raise ValueError(f"unknown fused_wire_encode backend {backend!r}")
    return fused_pack.pack_leaves_pallas(leaves, p_s, p_q,
                                         interpret=backend == "interpret")


def compress_roundtrip(x: jax.Array, p_s: float = 0.25, bits: int = 8,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool = None) -> jax.Array:
    """Kernel-backed lossy compress->decompress of an arbitrary tensor."""
    if interpret is None:
        interpret = not _NATIVE
    levels, scales = topk_quant(x.reshape(-1), p_s=p_s, bits=bits,
                                block=block, interpret=interpret)
    return dequant(levels, scales, bits, x.size, x.shape).astype(x.dtype)


def ssd(xh, b, c, dt, la, chunk: int, use_pallas: bool = True,
        interpret: bool = None):
    """Mamba2 SSD: kernel-backed or pure-jnp reference."""
    if interpret is None:
        interpret = not _NATIVE
    if use_pallas:
        return ssd_chunked_pallas(xh, b, c, dt, la, chunk,
                                  interpret=interpret)
    return ref.ssd_full_ref(xh, b, c, dt, la, chunk)
