"""Fixed-width bit-packing kernels for the packed wire format (Alg. 3).

``field_to_bits`` / ``bits_to_field`` are the vectorized (un)packers behind
``repro.core.codecs.PackedBitstreamCodec``: a field of ``k`` unsigned
integers at ``width`` bits becomes a flat MSB-first {0, 1} array that the
codec concatenates *bit-level* across fields and tensors (no per-tensor byte
padding), so the emitted byte count matches the analytic size model
``repro.core.compression.expected_pytree_wire_bytes`` exactly:

    bits(tensor) = k * (min(p_q, 32) + [k < n] * ceil(log2 n)) + 32
    len(stream)  = ceil(sum_over_tensors(bits) / 8)

``field_to_bits`` / ``bits_to_field`` are pure ``jnp`` shift/mask
arithmetic — elementwise VPU work that XLA lowers efficiently on TPU.  The
full one-pass TPU emitter (sparsify + quantize + shift/OR word packing fused
into a single Pallas kernel) lives in ``repro.kernels.fused_pack`` and is
surfaced through ``repro.kernels.ops.fused_wire_encode``.

The host-side helpers ``pack_segments`` / ``BitReader`` are the production
CPU path (per-segment jit dispatch + host<->device transfers cost ~4 ms each
on CPU, which would dominate the serial simulator's per-round encode).  They
work at WORD level: each ``width``-bit field spans at most two big-endian
uint32 stream words, so packing is a vectorized shift/OR scatter into words
(via ``np.add.at`` accumulation — contributions to one word never overlap
in bits, so the integer sum IS the bitwise OR) and reading is one 64-bit
gather + shift + mask per field.  No per-bit uint8 expansion
(``np.packbits`` / ``np.unpackbits``) anywhere — that costs 8x the memory
traffic of the payload and used to dominate packed-codec throughput.
tests/test_compression_invariants pins host-path == kernel-path bit
equality, and tests/test_fused_pack pins both against the fused emitter.

The normative stream layout these kernels serialize (field order,
offset-binary values, delta-coded indices, bit-level tensor concatenation)
is specified in **docs/WIRE_FORMAT.md**.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32                     # stream word size in bits (big-endian uint32)


@functools.partial(jax.jit, static_argnames=("width",))
def field_to_bits(vals: jax.Array, width: int) -> jax.Array:
    """(k,) unsigned ints -> (k*width,) uint8 bits, MSB first per value."""
    v = vals.astype(jnp.uint32).reshape(-1)
    shifts = jnp.arange(width - 1, -1, -1, dtype=jnp.uint32)
    return ((v[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.uint8).reshape(-1)


@functools.partial(jax.jit, static_argnames=("width",))
def bits_to_field(bits: jax.Array, width: int) -> jax.Array:
    """(k*width,) uint8 bits (MSB first) -> (k,) uint32 values."""
    b = bits.reshape(-1, width).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(width - 1, -1, -1, dtype=jnp.uint32)
    return jnp.sum(b * weights, axis=1, dtype=jnp.uint32)


Segment = Tuple[np.ndarray, int]          # (uint32 values, bit width)


def words_to_bytes(words: np.ndarray, total_bits: int) -> bytes:
    """Serialize big-endian uint32 stream words -> ``ceil(total_bits/8)``
    bytes.  Bits past ``total_bits`` must already be zero (they become the
    stream's zero-filled trailing partial byte)."""
    return np.ascontiguousarray(words, np.uint32).astype(">u4").tobytes()[
        :(total_bits + 7) // 8]


def _scatter_segment(acc: np.ndarray, v: np.ndarray, width: int,
                     pos: int) -> None:
    """Accumulate one fixed-width segment into a uint64 window accumulator.

    Field ``i`` occupies ``width`` bits starting at absolute stream bit
    ``off = pos + i * width`` (MSB-first).  A field starting in stream word
    ``off >> 5`` always ends within the 64-bit window covering that word
    and the next (``width <= 32``), so the whole field is ONE uint64
    contribution ``v << (64 - off%32 - width)`` to ``acc[off >> 5]`` —
    a single ``np.add.at`` per segment, no straddle case split.  Exact
    because fields are bit-disjoint in the stream: within a window the high
    and low 32-bit halves each sum without carries, so integer add IS
    bitwise OR.  ``width`` is a scalar per segment, keeping the shift
    arithmetic in a handful of flat int64/uint64 temporaries.
    """
    off = pos + np.arange(v.size, dtype=np.int64) * width
    sh = (np.int64(2 * WORD - width) - (off & 31)).astype(np.uint64)
    np.add.at(acc, off >> 5, v.astype(np.uint64) << sh)


def _fold_windows(acc: np.ndarray, total_bits: int) -> np.ndarray:
    """Collapse the uint64 window accumulator to big-endian uint32 words:
    stream word ``j`` = high half of window ``j`` OR low half of window
    ``j - 1`` (again bit-disjoint, so ``+`` is OR)."""
    nw = (total_bits + WORD - 1) // WORD
    words = (acc >> np.uint64(WORD)).astype(np.uint32)[:nw]
    words[1:] += acc.astype(np.uint32)[:nw - 1]
    return words


def pack_segments(segments: Sequence[Segment]) -> bytes:
    """Concatenate fixed-width fields into one bit-level stream.

    The final partial byte (if any) is zero-padded on the right, giving
    ``ceil(total_bits / 8)`` bytes.
    """
    parts: List[Tuple[np.ndarray, int, int]] = []
    pos = 0
    for v, width in segments:
        v = np.ascontiguousarray(v, dtype=np.uint32).reshape(-1)
        if v.size == 0:
            continue
        assert 1 <= width <= 32
        parts.append((v, width, pos))
        pos += v.size * width
    if not parts:
        return b""
    nw = (pos + WORD - 1) // WORD
    acc = np.zeros(nw, np.uint64)       # one 64-bit window per stream word
    for v, width, start in parts:
        _scatter_segment(acc, v, width, start)
    return words_to_bytes(_fold_windows(acc, pos), pos)


class BitReader:
    """Sequential fixed-width field reader over a packed byte stream.

    Word-level: the payload is viewed as big-endian uint32 words; each field
    is extracted from the (at most two) words it spans with one vectorized
    64-bit shift — ``(w[i] << 32 | w[i+1]) >> (64 - offset%32 - width)``.
    All arithmetic stays in uint64 (mixing uint64 with signed ints would
    silently promote to float64 in numpy).
    """

    def __init__(self, payload: bytes):
        pad = (-len(payload)) % 4 + 4     # +1 word so words[i+1] always exists
        self._words = np.frombuffer(payload + b"\x00" * pad,
                                    dtype=">u4").astype(np.uint64)
        self._nbits = len(payload) * 8
        self._pos = 0

    def read(self, count: int, width: int) -> np.ndarray:
        """Read ``count`` values of ``width`` bits each -> uint32 (count,)."""
        if count == 0:
            return np.zeros(0, np.uint32)
        nbits = count * width
        if self._pos + nbits > self._nbits:
            raise ValueError(
                f"bitstream underrun: wanted {nbits} bits at {self._pos}, "
                f"have {self._nbits - self._pos}")
        off = np.uint64(self._pos) \
            + np.arange(count, dtype=np.uint64) * np.uint64(width)
        wi = (off >> np.uint64(5)).astype(np.int64)
        comb = (self._words[wi] << np.uint64(32)) | self._words[wi + 1]
        shift = np.uint64(64) - (off & np.uint64(31)) - np.uint64(width)
        mask = np.uint64((1 << width) - 1)
        self._pos += nbits
        return ((comb >> shift) & mask).astype(np.uint32)

    @property
    def bits_read(self) -> int:
        return self._pos
