"""Fixed-width bit-packing kernels for the packed wire format (Alg. 3).

``field_to_bits`` / ``bits_to_field`` are the vectorized (un)packers behind
``repro.core.codecs.PackedBitstreamCodec``: a field of ``k`` unsigned
integers at ``width`` bits becomes a flat MSB-first {0, 1} array that the
codec concatenates *bit-level* across fields and tensors (no per-tensor byte
padding), so the emitted byte count matches the analytic size model
``repro.core.compression.expected_pytree_wire_bytes`` exactly:

    bits(tensor) = k * (min(p_q, 32) + [k < n] * ceil(log2 n)) + 32
    len(stream)  = ceil(sum_over_tensors(bits) / 8)

``field_to_bits`` / ``bits_to_field`` are pure ``jnp`` shift/mask
arithmetic — elementwise VPU work that XLA lowers efficiently on TPU (the
Pallas block variant of the *upstream* sparsify+quantize stage lives in
``repro.kernels.topk_quant``; packing itself has no block-local structure
worth a hand-written kernel).  The host-side helpers ``pack_segments`` /
``BitReader`` apply the SAME shift/mask formula in plain numpy — per-segment
jit dispatch + host<->device transfers cost ~4 ms each on CPU, which would
dominate the serial simulator's per-round encode — and materialize bytes
with ``np.packbits`` / ``np.unpackbits``.  tests/test_compression_invariants
pins host-path == kernel-path bit equality.

The normative stream layout these kernels serialize (field order,
offset-binary values, delta-coded indices, bit-level tensor concatenation)
is specified in **docs/WIRE_FORMAT.md**.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("width",))
def field_to_bits(vals: jax.Array, width: int) -> jax.Array:
    """(k,) unsigned ints -> (k*width,) uint8 bits, MSB first per value."""
    v = vals.astype(jnp.uint32).reshape(-1)
    shifts = jnp.arange(width - 1, -1, -1, dtype=jnp.uint32)
    return ((v[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.uint8).reshape(-1)


@functools.partial(jax.jit, static_argnames=("width",))
def bits_to_field(bits: jax.Array, width: int) -> jax.Array:
    """(k*width,) uint8 bits (MSB first) -> (k,) uint32 values."""
    b = bits.reshape(-1, width).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(width - 1, -1, -1, dtype=jnp.uint32)
    return jnp.sum(b * weights, axis=1, dtype=jnp.uint32)


Segment = Tuple[np.ndarray, int]          # (uint32 values, bit width)


def _np_field_to_bits(vals: np.ndarray, width: int) -> np.ndarray:
    """Host-side twin of ``field_to_bits`` (identical formula, no dispatch)."""
    shifts = np.arange(width - 1, -1, -1, dtype=np.uint32)
    return ((vals[:, None] >> shifts) & np.uint32(1)).astype(np.uint8).reshape(-1)


def pack_segments(segments: Sequence[Segment]) -> bytes:
    """Concatenate fixed-width fields into one bit-level stream.

    The final partial byte (if any) is zero-padded on the right by
    ``np.packbits``, giving ``ceil(total_bits / 8)`` bytes.
    """
    chunks: List[np.ndarray] = []
    for vals, width in segments:
        v = np.ascontiguousarray(vals, dtype=np.uint32).reshape(-1)
        if v.size == 0:
            continue
        assert 1 <= width <= 32
        chunks.append(_np_field_to_bits(v, width))
    if not chunks:
        return b""
    return np.packbits(np.concatenate(chunks)).tobytes()


class BitReader:
    """Sequential fixed-width field reader over a packed byte stream."""

    def __init__(self, payload: bytes):
        self._bits = np.unpackbits(np.frombuffer(payload, dtype=np.uint8))
        self._pos = 0

    def read(self, count: int, width: int) -> np.ndarray:
        """Read ``count`` values of ``width`` bits each -> uint32 (count,)."""
        if count == 0:
            return np.zeros(0, np.uint32)
        nbits = count * width
        seg = self._bits[self._pos:self._pos + nbits]
        if seg.size != nbits:
            raise ValueError(
                f"bitstream underrun: wanted {nbits} bits at {self._pos}, "
                f"have {self._bits.size - self._pos}")
        self._pos += nbits
        # host-side twin of bits_to_field (same formula, no jit dispatch)
        b = seg.reshape(count, width).astype(np.uint32)
        weights = np.uint32(1) << np.arange(width - 1, -1, -1, dtype=np.uint32)
        return (b * weights).sum(axis=1, dtype=np.uint32)

    @property
    def bits_read(self) -> int:
        return self._pos
