from repro.kernels.ops import compress_roundtrip, ssd
