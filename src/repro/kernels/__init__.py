from repro.kernels.bitpack import (BitReader, bits_to_field, field_to_bits,
                                   pack_segments)
from repro.kernels.ops import compress_roundtrip, ssd

__all__ = ["BitReader", "bits_to_field", "field_to_bits", "pack_segments",
           "compress_roundtrip", "ssd"]
