"""Pallas TPU kernel: Mamba2 SSD intra-chunk scan.

The quadratic within-chunk part of state-space duality is three MXU matmuls
per (batch, head, chunk) cell:
    CB   = C @ B^T                       (L x L)
    y    = (CB ∘ decay ∘ tril) @ X̄      (L x P)
    S_c  = (B ∘ decay_to_end)^T @ X̄     (N x P)
All operands for one grid cell live in VMEM (L=256, P=64, N<=128 =>
< 400 KiB).  The sequential inter-chunk recurrence (h = a h + S_c) stays in
a jax.lax.scan around the kernel — it is O(nc * N * P) and bandwidth-trivial.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xb_ref, b_ref, c_ref, cum_ref, y_ref, s_ref, a_ref):
    xb = xb_ref[0].astype(jnp.float32)              # (L, P)
    b = b_ref[0].astype(jnp.float32)                # (L, N)
    c = c_ref[0].astype(jnp.float32)                # (L, N)
    cum = cum_ref[0].astype(jnp.float32)            # (1, L) row vector
    cum = cum[0]                                    # (L,)
    L_ = xb.shape[0]

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L_, L_), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L_, L_), 1)
    # mask the exponent (upper triangle overflows exp -> inf -> nan grads)
    diff = jnp.where(ii >= jj, cum[:, None] - cum[None, :], -jnp.inf)
    m = jnp.exp(diff)
    y = (cb * m) @ xb                                          # (L, P)

    d2e = jnp.exp(cum[-1] - cum)                               # (L,)
    s = jax.lax.dot_general(b * d2e[:, None], xb,
                            (((0,), (0,)), ((), ())))          # (N, P)
    y_ref[0] = y.astype(y_ref.dtype)
    s_ref[0] = s.astype(s_ref.dtype)
    a_ref[...] = jnp.exp(cum[-1]).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(xb: jax.Array, b: jax.Array, c: jax.Array,
                    cum: jax.Array, *, interpret: bool = True
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched intra-chunk SSD.

    xb: (G, L, P) dt-scaled inputs (G = B*H*nc grid cells)
    b, c: (G, L, N); cum: (G, 1, L) cumulative log-decay.
    -> (y (G,L,P) f32, states (G,N,P) f32, chunk_decay (G,1) f32)
    """
    G, L, P = xb.shape
    N = b.shape[-1]
    y, s, a = pl.pallas_call(
        _kernel,
        grid=(G,),
        in_specs=[pl.BlockSpec((1, L, P), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, L, N), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, L, N), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, 1, L), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, L, P), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, N, P), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((G, L, P), jnp.float32),
                   jax.ShapeDtypeStruct((G, N, P), jnp.float32),
                   jax.ShapeDtypeStruct((G, 1), jnp.float32)],
        interpret=interpret,
    )(xb, b, c, cum)
    return y, s, a


def ssd_chunked_pallas(xh, b, c, dt, la, chunk: int, *,
                       interpret: bool = True):
    """Drop-in replacement for models.ssm.ssd_chunked using the kernel for
    the intra-chunk quadratic part.  Shapes as in ssd_chunked."""
    B, S, H, P = xh.shape
    N = b.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    xb = (xh.astype(jnp.float32) * dt[..., None]).reshape(B, nc, L, H, P)
    cum = jnp.cumsum(la.reshape(B, nc, L, H), axis=2)          # (B,nc,L,H)

    # -> grid cells (B, H, nc, ...)
    xg = jnp.transpose(xb, (0, 3, 1, 2, 4)).reshape(B * H * nc, L, P)
    bg = jnp.broadcast_to(b.reshape(B, 1, nc, L, N),
                          (B, H, nc, L, N)).reshape(-1, L, N)
    cg = jnp.broadcast_to(c.reshape(B, 1, nc, L, N),
                          (B, H, nc, L, N)).reshape(-1, L, N)
    cumg = jnp.transpose(cum, (0, 3, 1, 2)).reshape(-1, 1, L)

    y_i, s_c, a_c = ssd_intra_chunk(xg, bg, cg, cumg, interpret=interpret)
    y_i = y_i.reshape(B, H, nc, L, P)
    s_c = s_c.reshape(B, H, nc, N, P)
    a_c = a_c.reshape(B, H, nc)

    # inter-chunk recurrence (sequential, tiny)
    def scan_body(hprev, inp):
        s_ci, a_ci = inp                                       # (B,H,N,P),(B,H)
        hnew = a_ci[..., None, None] * hprev + s_ci
        return hnew, hprev

    hfin, hprevs = jax.lax.scan(
        scan_body, jnp.zeros((B, H, N, P), jnp.float32),
        (jnp.moveaxis(s_c, 2, 0), jnp.moveaxis(a_c, 2, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 2)                        # (B,H,nc,N,P)

    cc = c.reshape(B, nc, L, N).astype(jnp.float32)
    y_inter = jnp.einsum("bcln,bhcnp,bclh->bhclp", cc, hprevs,
                         jnp.exp(cum))
    y = (y_i + y_inter)                                        # (B,H,nc,L,P)
    y = jnp.transpose(y, (0, 2, 3, 1, 4)).reshape(B, S, H, P)
    return y.astype(xh.dtype), jnp.swapaxes(hfin, -1, -2)      # state (B,H,P,N)
