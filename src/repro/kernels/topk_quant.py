"""Pallas TPU kernel: fused block Top-K sparsification + int8/int4 quantization.

The paper's wire-compression hot spot (Alg. 3), TPU-adapted: instead of a
global sort (hostile to the VPU/MXU), each VMEM block finds its magnitude
threshold with a fixed-iteration binary search (vector compares + reductions
only), masks, and quantizes with a per-block max-abs scale.  Block-local K
approximates global Top-K; the approximation error is bounded by inter-block
magnitude skew and measured in tests/test_kernels.py.

Layout: x is reshaped to (M, BLOCK); grid = (M,); each program compresses one
BLOCK-sized row resident in VMEM.  Outputs: int8 levels (M, BLOCK) and f32
scales (M, 1).

In the FL stack this kernel is subsumed by the codec seam
(``repro.core.codecs``): ``ThresholdGraphCodec`` applies the same
binary-search threshold channel in-graph for the vectorized cohort trainer,
and ``PackedBitstreamCodec`` + ``repro.kernels.bitpack`` serialize the
quantized stream into actual wire bytes.  ``topk_quant`` remains the
block-local TPU formulation used by ``repro.kernels.ops.compress_roundtrip``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16384          # 64 KiB f32 per block — comfortably in VMEM


def _kernel(x_ref, levels_ref, scale_ref, *, p_s: float, bits: int,
            iters: int):
    x = x_ref[...]                                  # (1, BLOCK)
    ax = jnp.abs(x.astype(jnp.float32))
    hi0 = jnp.max(ax) + 1e-12
    lo0 = jnp.zeros((), jnp.float32)

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        frac = jnp.mean((ax >= mid).astype(jnp.float32))
        keep = frac > p_s
        return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    thr = 0.5 * (lo + hi)
    mask = ax >= thr
    kept = jnp.where(mask, x.astype(jnp.float32), 0.0)
    L = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(kept)), 1e-12)
    levels = jnp.clip(jnp.round(kept / scale * L), -L, L)
    levels_ref[...] = levels.astype(jnp.int8)
    scale_ref[...] = scale.reshape(1, 1)


@functools.partial(jax.jit,
                   static_argnames=("p_s", "bits", "iters", "block",
                                    "interpret"))
def topk_quant(x: jax.Array, *, p_s: float = 0.25, bits: int = 8,
               iters: int = 16, block: int = DEFAULT_BLOCK,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Compress a flat array: -> (levels int8 (M,block), scales f32 (M,1)).

    Pads x up to a multiple of ``block``.  ``interpret=True`` runs the kernel
    body in Python on CPU (this container has no TPU); on TPU pass False.
    """
    n = x.size
    m = -(-n // block)
    xp = jnp.zeros((m * block,), x.dtype).at[:n].set(x.reshape(-1))
    xp = xp.reshape(m, block)

    kern = functools.partial(_kernel, p_s=p_s, bits=bits, iters=iters)
    levels, scales = pl.pallas_call(
        kern,
        grid=(m,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, block), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(xp)
    return levels, scales


def dequant(levels: jax.Array, scales: jax.Array, bits: int,
            n: int, shape) -> jax.Array:
    L = 2 ** (bits - 1) - 1
    flat = (levels.astype(jnp.float32) * scales / L).reshape(-1)[:n]
    return flat.reshape(shape)
