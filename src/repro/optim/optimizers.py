"""Pure-JAX pytree optimizers (no optax in this container)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"mu": mu, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"mu": mu, "step": step}
        return jax.tree.map(lambda g: -lr_t * g, grads), {"mu": None, "step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p
            return u

        return (jax.tree.map(upd, m, v, params),
                {"m": m, "v": v, "step": step})

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return fn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
