"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
