"""Serving front door: batched decode plus a continuous-batching loop.

Two entry styles:

* Architecture demo — init random weights for a registry config and run
  the one-shot batched ``generate``::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

* FL -> serve bridge — load the trained global model out of a simulator
  checkpoint blob (``FLEngine.state_dict()`` or a fleet blob saved with
  ``repro.checkpoint.io.save_blob``) for an LM task and serve requests
  through the continuous-batching loop::

    PYTHONPATH=src python -m repro.launch.serve --from-sim ckpt.msgpack \
        --task transformer_lm --job 0 --batch 4 --requests 8 --gen 16

``ContinuousBatcher`` holds a fixed number of decode slots; each step it
admits queued requests into free slots (prefill one row, splice its KV
cache into the batched cache) and advances every active slot one token —
the maxtext-style admission loop, so short requests free their slot for
the queue instead of waiting for the longest sequence in the batch.
"""
from __future__ import annotations

import argparse
import collections
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import transformer as T


# ----------------------------------------------------------------------
# jit caches — keyed on the (frozen, hashable) ModelConfig so repeated
# generate()/ContinuousBatcher calls over the same config reuse the
# compiled step instead of re-tracing per call
# ----------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _serial_step(cfg):
    """(params, tok (B,1), pos scalar, cache) -> (logits, cache)."""
    return jax.jit(lambda p, t, pos, c: T.decode_step(p, t, pos, cfg, c))


@functools.lru_cache(maxsize=None)
def _prefill_jit(cfg):
    """Jitted decoder-only prefill (eager ``T.prefill`` costs hundreds of
    ms per call on the host — far more than the whole decode).  Shared by
    ``generate`` and ``ContinuousBatcher`` so a batcher admission runs the
    exact compiled program a solo generate does (token-parity).  One
    compile per (batch, prompt_len) shape."""
    return jax.jit(lambda p, toks: T.prefill(p, {"tokens": toks}, cfg))


@functools.lru_cache(maxsize=None)
def _extend_jit(cfg, cache_len):
    """Jitted ``extend_cache`` — zero-pads the sequence axis out to the
    resident ``cache_len``, same values as the eager path."""
    del cfg
    return jax.jit(lambda c: T.extend_cache(c, cache_len))


@functools.lru_cache(maxsize=None)
def _batched_step(cfg):
    """Per-row decode: tok (B,1) int32, pos (B,) int32 — each row advances
    at its OWN absolute position (slots hold requests of different ages).
    Wraps the scalar-position ``decode_step`` in a vmap over the batch
    axis (axis 1 of the stacked (L, B, ...) cache leaves), re-adding the
    size-1 batch dim inside.  Returns (next greedy token (B,), cache)."""

    def one(params, tok, pos, c):
        c1 = jax.tree.map(lambda a: a[:, None], c)
        logits, c1 = T.decode_step(params, tok[None, :], pos, cfg, c1)
        return logits[0, -1], jax.tree.map(lambda a: a[:, 0], c1)

    def step(params, toks, poss, cache):
        logits, cache = jax.vmap(one, in_axes=(None, 0, 0, 1),
                                 out_axes=(0, 1))(params, toks, poss, cache)
        # pos advances for every slot on-device; a free slot harmlessly
        # decodes garbage at a clamped position until it is re-admitted
        return (logits.argmax(-1).astype(jnp.int32)[:, None], poss + 1,
                cache)

    return jax.jit(step)


@functools.lru_cache(maxsize=None)
def _slot_insert(cfg):
    """Splice a freshly prefilled (extended) one-row cache into slot ``s``
    of the batched cache (axis 1), casting to the resident dtype, and set
    the slot's next-token / position registers — one dispatch per
    admission."""
    del cfg  # keyed per config only so unrelated models don't share

    def ins(cache, one, tok, pos, s, first, start):
        cache = jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), s, axis=1), cache, one)
        return cache, tok.at[s, 0].set(first), pos.at[s].set(start)

    return jax.jit(ins)


def generate(params, cfg, prompts: jnp.ndarray, gen: int, frames=None,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S) -> (B, S+gen) greedy/temperature sampling."""
    B, S = prompts.shape
    if cfg.is_encoder_decoder:
        logits, cache = T.encdec_prefill(
            params, {"tokens": prompts, "frames": frames}, cfg, cache_len=S)
    else:
        logits, cache = _prefill_jit(cfg)(params, prompts)
    cache = T.extend_cache(cache, S + gen)

    step = _serial_step(cfg)
    key = jax.random.PRNGKey(seed)
    out = [prompts]

    def sample(lg, key):
        if temperature <= 0:
            return lg.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / temperature, axis=-1).astype(jnp.int32)

    tok = sample(logits[:, -1], key)[:, None]
    for i in range(gen):
        out.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = step(params, tok, jnp.int32(S + i), cache)
        tok = sample(logits[:, -1], sub)[:, None]
    return jnp.concatenate(out, axis=1)


# ----------------------------------------------------------------------
# Continuous batching
# ----------------------------------------------------------------------

class ContinuousBatcher:
    """Fixed-slot greedy decode loop with per-step request admission.

    ``submit`` queues a request; each ``step`` first admits queued
    requests into free slots (one-row prefill -> ``extend_cache`` ->
    dynamic-slice splice into the batched cache) and then advances every
    active slot one greedy token at its own position.  A slot frees the
    moment its request reaches ``gen`` tokens, so the queue drains
    continuously instead of in lock-step batches.  Greedy only: the
    tokens of a request admitted mid-flight match a solo ``generate`` of
    the same prompt (tests/test_serve.py pins this)."""

    def __init__(self, params, cfg, slots: int = 4, cache_len: int = 64):
        self.params = params
        self.cfg = cfg
        self.slots = int(slots)
        self.cache_len = int(cache_len)
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        self._rid = [-1] * self.slots            # request id per slot
        self._remaining = np.zeros(self.slots, np.int64)
        # decode registers live on-device so the loop never syncs per step
        self._tok = jnp.zeros((self.slots, 1), jnp.int32)
        self._pos = jnp.zeros(self.slots, jnp.int32)
        self._cache = None                       # built on first admission
        self._trace: List[Any] = []              # per-step (B,1) token arrays
        self._first: Dict[int, int] = {}         # rid -> prefill argmax token
        self._slots_of: Dict[int, List[Tuple[int, int]]] = {}
        self._results: Dict[int, List[int]] = {}  # materialized on demand
        self.steps = 0                           # decode steps taken

    # -- request intake --------------------------------------------------
    def submit(self, prompt: np.ndarray, gen: int) -> int:
        """Queue a request; returns its id.  ``prompt`` is a 1-D int32
        token array; ``gen`` >= 1 tokens will be generated."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if gen < 1:
            raise ValueError("gen must be >= 1")
        if prompt.size + gen > self.cache_len:
            raise ValueError(f"prompt ({prompt.size}) + gen ({gen}) exceeds "
                             f"cache_len ({self.cache_len})")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, prompt, int(gen)))
        return rid

    def result(self, rid: int) -> List[int]:
        """Generated tokens so far for request ``rid`` (length ``gen``
        once the request has completed).  Token values are pulled off the
        device lazily here; the decode loop itself never syncs."""
        if rid not in self._results:
            toks = [self._first[rid]]
            toks += [int(np.asarray(self._trace[k])[s, 0])
                     for k, s in self._slots_of[rid]]
            if not any(r == rid for r in self._rid):   # completed: freeze
                self._results[rid] = toks
            return toks
        return list(self._results[rid])

    def pending(self) -> bool:
        return bool(self._queue) or any(r >= 0 for r in self._rid)

    # -- the loop --------------------------------------------------------
    def _admit(self) -> List[int]:
        """Fill free slots from the queue.  Returns rids that completed
        at admission (gen == 1: the prefill token is the whole answer)."""
        done = []
        for s in range(self.slots):
            if self._rid[s] >= 0 or not self._queue:
                continue
            rid, prompt, gen = self._queue.popleft()
            logits, one = _prefill_jit(self.cfg)(
                self.params, jnp.asarray(prompt[None, :]))
            one = _extend_jit(self.cfg, self.cache_len)(one)
            first = int(jnp.argmax(logits[0, -1]))
            self._first[rid] = first
            self._slots_of[rid] = []
            if gen == 1:
                done.append(rid)
                continue
            if self._cache is None:
                self._cache = jax.tree.map(
                    lambda a: jnp.zeros(
                        a.shape[:1] + (self.slots,) + a.shape[2:], a.dtype),
                    one)
            self._cache, self._tok, self._pos = _slot_insert(self.cfg)(
                self._cache, one, self._tok, self._pos, jnp.int32(s),
                jnp.int32(first), jnp.int32(prompt.size))
            self._rid[s] = rid
            self._remaining[s] = gen - 1
        return done

    def step(self) -> List[int]:
        """Admit from the queue, then advance every active slot one
        token.  Returns the rids that completed this step."""
        done = self._admit()
        if not any(r >= 0 for r in self._rid):
            return done
        self._tok, self._pos, self._cache = _batched_step(self.cfg)(
            self.params, self._tok, self._pos, self._cache)
        self._trace.append(self._tok)
        k = self.steps
        self.steps += 1
        for s in range(self.slots):
            if self._rid[s] < 0:
                continue  # free slot decodes garbage harmlessly
            self._slots_of[self._rid[s]].append((k, s))
            self._remaining[s] -= 1
            if self._remaining[s] == 0:
                done.append(self._rid[s])
                self._rid[s] = -1
        return done

    def run(self, prompts, gen: int) -> Tuple[List[List[int]], List[float]]:
        """Drive a workload to completion: submit every prompt up front,
        step until the queue drains.  Returns (per-request token lists,
        per-request wall-clock completion latencies in seconds, both in
        submit order).  Latency stamps block on the completing step's
        device values, so they measure computed tokens, not dispatches."""
        rids = [self.submit(p, gen) for p in prompts]
        t0 = time.time()
        lat: Dict[int, float] = {}
        while self.pending():
            finished = self.step()
            if finished:
                if self._trace:
                    jax.block_until_ready(self._trace[-1])
                now = time.time() - t0
                for rid in finished:
                    lat[rid] = now
        return [self.result(r) for r in rids], [lat[r] for r in rids]


# ----------------------------------------------------------------------
# FL -> serve bridge
# ----------------------------------------------------------------------

def load_task_params(path: str, task_name: str, job: int = 0):
    """Rebuild a trained LM's weights from a simulator checkpoint blob.

    Resolves ``task_name`` in the FL task registry for the treedef
    template and the transformer ``ModelConfig``, then pulls the global
    weights out of the engine/fleet blob at ``path`` (``job`` picks the
    task slot inside a fleet blob).  Returns ``(params, cfg)``."""
    from repro.checkpoint.io import load_sim_params
    from repro.fl.tasks import get_task
    task = get_task(task_name)
    if task.model_cfg is None:
        raise ValueError(f"task {task_name!r} is not an LM family — "
                         "it has no transformer ModelConfig to serve")
    like = task.init_params(jax.random.PRNGKey(0))
    params = load_sim_params(path, like, task=job)
    return params, task.model_cfg


def serve_from_sim(path: str, task_name: str, job: int, batch: int,
                   requests: int, prompt_len: int, gen: int,
                   seed: int = 0) -> None:
    params, cfg = load_task_params(path, task_name, job)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab, prompt_len).astype(np.int32)
               for _ in range(requests)]
    cb = ContinuousBatcher(params, cfg, slots=batch,
                           cache_len=prompt_len + gen)
    t0 = time.time()
    outs, lat = cb.run(prompts, gen)
    dt = time.time() - t0
    toks = sum(len(o) for o in outs)
    print(f"[serve] {cfg.name} from {path}: {requests} requests x gen={gen} "
          f"over {batch} slots in {dt:.2f}s ({toks / dt:.1f} tok/s, "
          f"p50 latency {np.percentile(lat, 50) * 1e3:.0f} ms)")
    print("[serve] first request tokens:", outs[0])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--from-sim", default=None, metavar="CKPT",
                    help="serve trained weights from an engine/fleet "
                         "checkpoint blob instead of random --arch init")
    ap.add_argument("--task", default="transformer_lm",
                    help="FL task registry name behind --from-sim")
    ap.add_argument("--job", type=int, default=0,
                    help="task slot inside a fleet checkpoint blob")
    ap.add_argument("--requests", type=int, default=8,
                    help="workload size for the continuous-batching loop")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.from_sim is not None:
        serve_from_sim(args.from_sim, args.task, args.job, args.batch,
                       args.requests, args.prompt_len, args.gen, args.seed)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(
            rng.randn(args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    seqs = generate(params, cfg, prompts, args.gen, frames,
                    args.temperature, args.seed)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] first sequence tail:", np.asarray(seqs[0, -8:]).tolist())


if __name__ == "__main__":
    main()
