"""Batched serving driver: prefill a batch of prompts, then decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import transformer as T


def generate(params, cfg, prompts: jnp.ndarray, gen: int, frames=None,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S) -> (B, S+gen) greedy/temperature sampling."""
    B, S = prompts.shape
    if cfg.is_encoder_decoder:
        logits, cache = T.encdec_prefill(
            params, {"tokens": prompts, "frames": frames}, cfg, cache_len=S)
    else:
        logits, cache = T.prefill(params, {"tokens": prompts}, cfg)
    cache = T.extend_cache(cache, S + gen)

    step = jax.jit(lambda p, t, pos, c: T.decode_step(p, t, pos, cfg, c))
    key = jax.random.PRNGKey(seed)
    out = [prompts]

    def sample(lg, key):
        if temperature <= 0:
            return lg.argmax(-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / temperature, axis=-1).astype(jnp.int32)

    tok = sample(logits[:, -1], key)[:, None]
    for i in range(gen):
        out.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = step(params, tok, jnp.int32(S + i), cache)
        tok = sample(logits[:, -1], sub)[:, None]
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.RandomState(args.seed)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(
            rng.randn(args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    seqs = generate(params, cfg, prompts, args.gen, frames,
                    args.temperature, args.seed)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] first sequence tail:", np.asarray(seqs[0, -8:]).tolist())


if __name__ == "__main__":
    main()
