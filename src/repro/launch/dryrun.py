import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay first: jax locks the device count on first
initialization, and only the dry-run wants 512 placeholder host devices.

For each combination this lowers the appropriate step
  train_4k     -> fed_train_step (TEASQ-Fed round: local prox steps +
                  compressed delta exchange + staleness-weighted merge)
                  or plain_train_step with --step plain
  prefill_32k  -> serve prefill (full prompt -> last logits + KV cache)
  decode_32k   -> serve decode (1 token, full 32k KV cache)
  long_500k    -> serve decode (1 token, rolling 8k window / SSM state)
compiles it, and records memory_analysis / cost_analysis / HLO collective
bytes into a JSON that benchmarks/roofline.py turns into EXPERIMENTS.md.
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.fed_step import FedConfig, fed_wire_bytes, make_fed_train_step
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.sharding.rules import Rules, param_shardings, use_rules

TRANSFORMER_ARCHS = tuple(a for a in ARCH_IDS if a != "fmnist_cnn")


# ----------------------------------------------------------------------
# step builders
# ----------------------------------------------------------------------
def build_train(cfg, rules, fed: FedConfig, plain: bool, remat: bool = True,
                loss_chunk: int = 0):
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg, remat=remat,
                                     loss_chunk=loss_chunk)[0]
    if plain:
        def step(params, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new = jax.tree.map(lambda p, g: (p - 1e-3 * g).astype(p.dtype),
                               params, grads)
            return new, loss
        return step, False
    return make_fed_train_step(loss_fn, fed), True


def build_args_train(cfg, shape_name, rules, fed: Optional[FedConfig]):
    params = S.param_specs(cfg)
    batch = S.batch_specs(cfg, shape_name)
    in_sh = [param_shardings(rules, params), S.batch_shardings(rules, batch)]
    args = [params, batch]
    if fed is not None:
        args.append(jax.ShapeDtypeStruct((fed.n_groups,), jnp.int32))
        in_sh.append(NamedSharding(rules.mesh, P()))
    return args, in_sh


def build_prefill(cfg, rules):
    if cfg.is_encoder_decoder:
        def step(params, batch):
            return T.encdec_prefill(params, batch, cfg,
                                    cache_len=batch["tokens"].shape[1])
    else:
        def step(params, batch):
            return T.prefill(params, batch, cfg)
    return step


def build_decode(cfg, shape_name, rules, seq_shard_kv: bool = False,
                 kv_quant: bool = False):
    _, _, _, rolling = S.decode_specs(cfg, shape_name, quantized=kv_quant)

    def step(params, tok, pos, cache):
        return T.decode_step(params, tok["tokens"], pos, cfg, cache,
                             rolling=rolling, seq_shard_kv=seq_shard_kv)

    return step


# ----------------------------------------------------------------------
# HLO collective accounting
# ----------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f32": 4, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "f64": 8}
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _result_shape_bytes(line: str, op_start: int) -> int:
    """Bytes of the result shape: between '=' and the op name."""
    eq = line.find("=")
    if eq < 0 or eq >= op_start:
        seg = line
    else:
        seg = line[eq + 1:op_start]
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_dev: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return n_dev


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _parse_computations(hlo_text: str):
    """Split optimized HLO text into computations with their instructions."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s:
            continue
        if not line.startswith(" ") and ("->" in s) and s.endswith("{"):
            m = _COMP_RE.match(s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None and s != "}":
            comps[cur].append(s)
    return comps, entry


_DOT_RE = re.compile(r"=\s+\S+\s+dot\(([^)]*)\)")
# one dot operand: optional inline typed shape (newer HLO prints
# ``dot(f32[128,256]{1,0} %lhs, ...)``) followed by the instruction name
_DOT_OPERAND_RE = re.compile(
    r"(?:[a-z][a-z0-9]*"          # any element type (f32, s16, f8e4m3fn, ...)
    r"\[(?P<dims>[0-9,]*)\]\S*\s+)?%?(?P<name>[\w.\-]+)")
_FUSION_RE = re.compile(r"\bfusion\(.*?calls=%?([\w.\-]+)")
_CONTR_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONV_RE = re.compile(r"=\s+\S+\s+convolution\(")
_NAME_SHAPE_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))")


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


def hlo_flops(hlo_text: str) -> float:
    """Trip-count-aware dot/conv FLOPs.

    XLA's cost_analysis() counts each while body ONCE regardless of trip
    count (verified empirically), so scanned layer stacks are undercounted
    by ~n_layers.  This walks the computation graph like collective_bytes(),
    multiplying loop bodies by their trip counts, and counts
    2 * prod(result_dims) * prod(contracted lhs dims) per dot.
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return 0.0

    # symbol tables: computation -> {instr name -> dims list}
    tables: Dict[str, Dict[str, list]] = {}
    for cname, instrs in comps.items():
        tab = {}
        for ins in instrs:
            m = _NAME_SHAPE_RE.match(ins)
            if m:
                dims = _shape_dims(m.group(2))
                if dims is not None:
                    tab[m.group(1)] = dims
        tables[cname] = tab

    def trip_count(cond):
        best = 1
        for ins in comps.get(cond, ()):
            for mm in _TRIP_RE.finditer(ins):
                best = max(best, int(mm.group(1)))
        return best

    def comp_flops(name: str, depth: int = 0) -> float:
        if depth > 50:
            return 0.0
        total = 0.0
        tab = tables.get(name, {})
        for ins in comps.get(name, ()):
            dm = _DOT_RE.search(ins)
            if dm:
                nm = _NAME_SHAPE_RE.match(ins)
                res = _shape_dims(nm.group(2)) if nm else None
                ops = _DOT_OPERAND_RE.finditer(dm.group(1))
                lhs = next(ops, None)
                cm = _CONTR_RE.search(ins)
                k = 1
                if cm and lhs:
                    # lhs shape: inline typed operand when present, else the
                    # producing instruction's result shape from the table
                    if lhs.group("dims") is not None:
                        lhs_dims = [int(d) for d in
                                    lhs.group("dims").split(",") if d]
                    else:
                        lhs_dims = tab.get(lhs.group("name"))
                    if lhs_dims:
                        for i in (int(x) for x in cm.group(1).split(",") if x):
                            if i < len(lhs_dims):
                                k *= lhs_dims[i]
                if res:
                    n = 1
                    for d in res:
                        n *= d
                    total += 2.0 * n * k
            if _WHILE_RE.search(ins):
                b, c = _BODY_RE.search(ins), _COND_RE.search(ins)
                if b:
                    t = trip_count(c.group(1)) if c else 1
                    total += t * comp_flops(b.group(1), depth + 1)
            else:
                fm = _FUSION_RE.search(ins)
                cm2 = _CALL_RE.search(ins)
                if fm:
                    total += comp_flops(fm.group(1), depth + 1)
                elif cm2:
                    total += comp_flops(cm2.group(1), depth + 1)
        return total

    return comp_flops(entry)


_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "bitcast-convert(")


def hlo_bytes(hlo_text: str) -> float:
    """Trip-count-aware HBM byte traffic estimate: per top-level instruction
    (fusions count their operands + result once; loop bodies x trip count)."""
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return 0.0
    _dtype_re = _SHAPE_RE

    def line_bytes(ins: str, op_start: int, tab) -> float:
        res = _result_shape_bytes(ins, op_start)
        args = ins[op_start:]
        # slicing ops (incl. fusions wrapping them) touch only the slice:
        # bytes = 2 * smallest participating buffer
        if "dynamic-update-slice" in ins or "dynamic-slice" in ins \
                or " slice(" in ins:
            m = re.search(r"\(([^)]*)\)", args)
            sizes = [res] if res else []
            if m:
                for o in m.group(1).split(","):
                    d = tab.get(o.strip().lstrip("%"))
                    if d:
                        sizes.append(d)
            return 2.0 * min(sizes) if sizes else 0.0
        total = res
        m = re.search(r"\(([^)]*)\)", args)
        if m:
            for o in m.group(1).split(","):
                o = o.strip().lstrip("%")
                d = tab.get(o)
                if d:
                    total += d
        return total

    # per-computation: name -> bytes of each instruction's result
    tables: Dict[str, Dict[str, float]] = {}
    for cname, instrs in comps.items():
        tab = {}
        for ins in instrs:
            m = _NAME_SHAPE_RE.match(ins)
            if m:
                eq = ins.find("=")
                tab[m.group(1)] = _result_shape_bytes(ins, len(ins)) if eq < 0 \
                    else _result_shape_bytes(ins, _op_start_after_eq(ins))
        tables[cname] = tab

    def trip_count(cond):
        best = 1
        for ins in comps.get(cond, ()):
            for mm in _TRIP_RE.finditer(ins):
                best = max(best, int(mm.group(1)))
        return best

    def walk(name: str, depth: int = 0) -> float:
        if depth > 50:
            return 0.0
        total = 0.0
        tab = tables.get(name, {})
        for ins in comps.get(name, ()):
            if any(s in ins for s in _SKIP_OPS):
                continue
            if _WHILE_RE.search(ins):
                b, c = _BODY_RE.search(ins), _COND_RE.search(ins)
                if b:
                    total += (trip_count(c.group(1)) if c else 1) * \
                        walk(b.group(1), depth + 1)
                continue
            cm2 = _CALL_RE.search(ins)
            if cm2 and " call(" in ins:
                total += walk(cm2.group(1), depth + 1)
                continue
            ostart = _op_start_after_eq(ins)
            total += line_bytes(ins, ostart, tab)
        return total

    return walk(entry)


def _op_start_after_eq(ins: str) -> int:
    eq = ins.find("=")
    if eq < 0:
        return 0
    m = re.match(r"\s*(?:\([^)]*\)|\S+)\s", ins[eq + 1:])
    return eq + 1 + (m.end() if m else 0)


def collective_bytes(hlo_text: str, n_dev: int) -> Dict[str, float]:
    """Per-device link bytes by collective kind, trip-count aware.

    Ring estimates: all-gather: out_bytes*(g-1)/g; all-reduce: 2*b*(g-1)/g;
    reduce-scatter / all-to-all / permute: b*(g-1)/g.  HLO shapes are
    per-partition in SPMD modules.  Collectives inside ``while`` bodies
    (lax.scan over layers / chunks) are multiplied by the loop trip count
    parsed from the loop condition's comparison constant.
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return {"total": 0.0}

    def trip_count(cond_name: str) -> int:
        best = 1
        for ins in comps.get(cond_name, ()):
            if "compare" in ins or "constant" in ins:
                for mm in _TRIP_RE.finditer(ins):
                    best = max(best, int(mm.group(1)))
        return best

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        acc: Dict[str, float] = {}
        memo[name] = acc  # cycle guard
        for ins in comps.get(name, ()):
            mm = _COLL_RE.search(ins)
            if mm and "-done" not in ins[:mm.end()]:
                kind = mm.group(1)
                nbytes = _result_shape_bytes(ins, mm.start(1))
                g = _group_size(ins, n_dev)
                frac = (g - 1) / g if g > 1 else 0.0
                moved = (2 if kind == "all-reduce" else 1) * nbytes * frac
                acc[kind] = acc.get(kind, 0.0) + moved
                acc[kind + "_count"] = acc.get(kind + "_count", 0) + 1
            if _WHILE_RE.search(ins):
                b = _BODY_RE.search(ins)
                c = _COND_RE.search(ins)
                if b:
                    t = trip_count(c.group(1)) if c else 1
                    sub = walk(b.group(1))
                    for k, v in sub.items():
                        acc[k] = acc.get(k, 0.0) + t * v
            else:
                cm = _CALL_RE.search(ins)
                if cm:
                    sub = walk(cm.group(1))
                    for k, v in sub.items():
                        acc[k] = acc.get(k, 0.0) + v
        return acc

    out = dict(walk(entry))
    out["total"] = sum(v for k, v in out.items() if not k.endswith("_count"))
    return out


# ----------------------------------------------------------------------
def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            step_kind: str = "fed", fed_schedule: str = "gather_q",
            local_steps: int = 1, p_q: int = 8, loss_chunk: int = 0,
            seq_shard_kv: bool = False, kv_quant: bool = False,
            group_parallelism: str = "tp",
            variant: str = "", verbose: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = Rules(mesh)
    n_dev = mesh.size
    shape = INPUT_SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step_kind if shape.kind == "train" else shape.kind,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if variant:
        rec["variant"] = variant

    with use_rules(rules):
        if shape.kind == "train":
            n_groups = mesh.shape.get("pod", 1) * mesh.shape["data"]
            fed = None
            if step_kind == "fed":
                fed = FedConfig(n_groups=n_groups, local_steps=local_steps,
                                schedule=fed_schedule, p_q=p_q,
                                group_parallelism=group_parallelism)
                rec["fed"] = {"n_groups": n_groups, "schedule": fed_schedule,
                              "p_q": p_q, "local_steps": local_steps}
                rec["wire"] = fed_wire_bytes(S.param_specs(cfg), fed, n_groups)
            fn, _ = build_train(cfg, rules, fed, plain=step_kind == "plain",
                                loss_chunk=loss_chunk)
            args, in_sh = build_args_train(cfg, shape_name, rules, fed)
        elif shape.kind == "prefill":
            fn = build_prefill(cfg, rules)
            params = S.param_specs(cfg)
            batch = S.batch_specs(cfg, shape_name)
            args = [params, batch]
            in_sh = [param_shardings(rules, params),
                     S.batch_shardings(rules, batch)]
        else:  # decode
            fn = build_decode(cfg, shape_name, rules,
                              seq_shard_kv=seq_shard_kv, kv_quant=kv_quant)
            params = S.param_specs(cfg)
            tok, cache, pos, rolling = S.decode_specs(cfg, shape_name,
                                                      quantized=kv_quant)
            rec["rolling_window"] = bool(rolling) and S.WINDOW or 0
            args = [params, tok, pos, cache]
            in_sh = [param_shardings(rules, params),
                     S.batch_shardings(rules, tok),
                     NamedSharding(mesh, P()),
                     S.cache_shardings(rules, cache,
                                       seq_shard=seq_shard_kv)]

        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

    rec["lower_s"] = round(t_lower - t0, 1)
    rec["compile_s"] = round(t_compile - t_lower, 1)

    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       (k in ("flops", "bytes accessed") or
                        k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = str(e)
    try:
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt, n_dev)
        # trip-count-aware corrections (XLA cost_analysis counts while
        # bodies once; scanned stacks undercount by ~n_layers)
        rec.setdefault("cost", {})["flops_trip_aware"] = hlo_flops(txt)
        rec["cost"]["bytes_trip_aware"] = hlo_bytes(txt)
    except Exception as e:  # pragma: no cover
        rec["collectives_error"] = str(e)

    if verbose:
        flops = rec.get("cost", {}).get("flops", 0)
        coll = rec.get("collectives", {}).get("total", 0)
        print(f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
              f"lower={rec['lower_s']:6.1f}s compile={rec['compile_s']:6.1f}s "
              f"flops/dev={flops:.3e} coll/dev={coll:.3e}B", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (10 assigned archs)")
    ap.add_argument("--shape", default="all",
                    help="input shape or 'all' (4 assigned shapes)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--step", default="fed", choices=["fed", "plain"])
    ap.add_argument("--fed-schedule", default="gather_q",
                    choices=["gather_q", "gather_f32", "psum"])
    ap.add_argument("--p-q", type=int, default=8)
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = TRANSFORMER_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("step"),
             r.get("fed", {}).get("schedule")) for r in results}

    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, "2x16x16" if mp else "16x16",
                       args.step if INPUT_SHAPES[shape].kind == "train"
                       else INPUT_SHAPES[shape].kind,
                       args.fed_schedule if (INPUT_SHAPES[shape].kind == "train"
                                             and args.step == "fed") else None)
                if key in done:
                    continue
                try:
                    rec = run_one(arch, shape, multi_pod=mp,
                                  step_kind=args.step,
                                  fed_schedule=args.fed_schedule,
                                  p_q=args.p_q)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "step": args.step, "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"[dryrun] FAIL {arch} {shape}: {e!r}", flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_fail = sum(1 for r in results if "error" in r)
    print(f"[dryrun] complete: {len(results)} records, {n_fail} failures")


if __name__ == "__main__":
    main()
