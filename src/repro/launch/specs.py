"""ShapeDtypeStruct input specs for every (arch x input-shape) pair.

No device allocation: everything here is abstract (eval_shape / SDS), the
same pattern as lowering against placeholder inputs.  Modality frontends
(whisper mel+conv, VLM ViT) are stubbed per the assignment: specs include
precomputed frame/patch embeddings.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, get_config
from repro.models import transformer as T
from repro.sharding.rules import Rules, logical_axes_for, param_shardings

PARAM_DTYPE = jnp.bfloat16
WINDOW = 8192                  # sliding-window size for long_500k decode


def param_specs(cfg: ModelConfig, dtype=PARAM_DTYPE):
    """Abstract parameter pytree via eval_shape (no allocation)."""
    fn = partial(T.init_model, cfg=cfg, dtype=dtype)
    return jax.eval_shape(fn, jax.random.PRNGKey(0))


def batch_specs(cfg: ModelConfig, shape_name: str,
                n_groups: int = 1) -> Dict[str, SDS]:
    """Training / prefill batch ShapeDtypeStructs."""
    s = INPUT_SHAPES[shape_name]
    B = s.global_batch
    out = {"tokens": SDS((B, s.seq_len), jnp.int32)}
    if cfg.is_encoder_decoder:
        out["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), PARAM_DTYPE)
    if cfg.n_patches:
        out["patches"] = SDS((B, cfg.n_patches, cfg.d_model), PARAM_DTYPE)
    return out


def decode_specs(cfg: ModelConfig, shape_name: str, quantized: bool = False
                 ) -> Tuple[Dict[str, SDS], Any, SDS, bool]:
    """-> (token batch, cache pytree specs, pos spec, rolling)."""
    s = INPUT_SHAPES[shape_name]
    B = s.global_batch
    rolling = shape_name == "long_500k" and not (cfg.is_ssm_only)
    cache_len = min(s.seq_len, WINDOW) if rolling else s.seq_len
    cache = jax.eval_shape(
        partial(T.init_decode_state, cfg, B, cache_len, PARAM_DTYPE, rolling,
                quantized=quantized))
    tok = {"tokens": SDS((B, 1), jnp.int32)}
    return tok, cache, SDS((), jnp.int32), rolling


# ----------------------------------------------------------------------
# shardings
# ----------------------------------------------------------------------
def batch_shardings(rules: Rules, batch):
    def f(x):
        return rules.sharding(("batch",) + (None,) * (x.ndim - 1), x.shape)
    return jax.tree.map(f, batch)


_CACHE_LOGICAL = {
    "k": ("stack", "batch", "seq", "kv_heads", "head_dim"),
    "v": ("stack", "batch", "seq", "kv_heads", "head_dim"),
    "k_scale": ("stack", "batch", "seq", "kv_heads"),
    "v_scale": ("stack", "batch", "seq", "kv_heads"),
    "xk": ("stack", "batch", "seq", "kv_heads", "head_dim"),
    "xv": ("stack", "batch", "seq", "kv_heads", "head_dim"),
    "state": ("stack", "batch", "ssm_heads", None, None),
    "conv": ("stack", "batch", None, None),
}

# sequence-sharded KV variant: cache seq axis on the 'model' mesh axis
_CACHE_LOGICAL_SEQSHARD = dict(
    _CACHE_LOGICAL,
    k=("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
    v=("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
)


def cache_shardings(rules: Rules, cache, seq_shard: bool = False):
    table = _CACHE_LOGICAL_SEQSHARD if seq_shard else _CACHE_LOGICAL
    if seq_shard:
        rules = rules.with_overrides(kv_seq="model")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        name = None
        for p in path:
            if hasattr(p, "key"):
                name = str(p.key)
        logical = table.get(name, (None,) * leaf.ndim)
        if len(logical) != leaf.ndim:  # hybrid: extra stacked axis
            logical = ("stack",) + tuple(logical)
        logical = logical[:leaf.ndim]
        out.append(rules.sharding(logical, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)
