"""Runnable trainer (single host): TEASQ-Fed rounds or plain SGD on any
assigned architecture at reduced (smoke) or full scale.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --mode fed --groups 4 --local-steps 2 --steps 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs.base import get_config, get_smoke_config
from repro.core.fed_step import FedConfig, make_fed_train_step
from repro.data import make_token_batch
from repro.models import transformer as T
from repro.optim import adamw, apply_updates, clip_by_global_norm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--mode", default="plain", choices=["plain", "fed"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--fed-schedule", default="gather_q")
    ap.add_argument("--mu", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} family={cfg.family}")
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {n_params/1e6:.2f}M params")

    rng = np.random.RandomState(args.seed)

    def make_batch():
        b = make_token_batch(rng, args.batch, args.seq, cfg.vocab)
        batch = {"tokens": jnp.asarray(b["tokens"])}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.asarray(
                rng.randn(args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.n_patches:
            batch["patches"] = jnp.asarray(
                rng.randn(args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        return batch

    if args.mode == "fed":
        fed = FedConfig(n_groups=args.groups, local_steps=args.local_steps,
                        lr=args.lr, mu=args.mu, schedule=args.fed_schedule)
        step = jax.jit(make_fed_train_step(
            lambda p, b: T.lm_loss(p, b, cfg)[0], fed))
        stale = jnp.zeros((args.groups,), jnp.int32)
        for i in range(args.steps):
            t0 = time.time()
            params, m = step(params, make_batch(), stale)
            print(f"[fed round {i:3d}] loss={float(m['local_loss']):.4f} "
                  f"alpha_t={float(m['alpha_t']):.3f} "
                  f"({time.time()-t0:.2f}s)", flush=True)
    else:
        opt = adamw(args.lr)
        opt_state = opt.init(params)

        @jax.jit
        def step(p, s, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: T.lm_loss(q, batch, cfg), has_aux=True)(p)
            grads, gn = clip_by_global_norm(grads, 1.0)
            upd, s = opt.update(grads, s, p)
            return apply_updates(p, upd), s, loss, gn

        for i in range(args.steps):
            t0 = time.time()
            params, opt_state, loss, gn = step(params, opt_state, make_batch())
            print(f"[step {i:3d}] loss={float(loss):.4f} "
                  f"gnorm={float(gn):.2f} ({time.time()-t0:.2f}s)", flush=True)

    if args.ckpt:
        save_pytree(args.ckpt, params)
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
