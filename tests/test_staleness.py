"""Staleness-weighted aggregation (Eqs. 6-10) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.staleness import (aggregate_cache, merge_global, mixing_alpha,
                                  staleness_weight, weighted_average)


def test_eq6_staleness_weight():
    # S(s) = (s+1)^-a
    assert float(staleness_weight(0, 0.5)) == 1.0
    np.testing.assert_allclose(float(staleness_weight(3, 0.5)), 0.5)
    np.testing.assert_allclose(float(staleness_weight(1, 1.0)), 0.5)


def test_eq7_weighted_average_exact():
    u1 = {"w": jnp.asarray([1.0, 0.0])}
    u2 = {"w": jnp.asarray([0.0, 1.0])}
    # staleness 0 vs 3 (a=0.5 -> weights 1, 0.5), n = 100, 200
    u = weighted_average([u1, u2], [0, 3], [100, 200], a=0.5)
    # weights: 1*100=100, 0.5*200=100 -> equal mix
    np.testing.assert_allclose(np.asarray(u["w"]), [0.5, 0.5], atol=1e-6)


def test_eq9_eq10_merge():
    w = {"w": jnp.asarray([0.0])}
    u = {"w": jnp.asarray([1.0])}
    a_t = mixing_alpha([0, 0], alpha=0.6, a=0.5)
    np.testing.assert_allclose(float(a_t), 0.6)
    out = merge_global(w, u, a_t)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.6], atol=1e-6)


def test_staler_updates_matter_less():
    w = {"w": jnp.zeros(3)}
    fresh = ({"w": jnp.ones(3)}, 10, 100)    # h_c = t  -> staleness 0
    stale = ({"w": -jnp.ones(3)}, 0, 100)    # h_c = 0  -> staleness 10
    out = aggregate_cache(w, [fresh, stale], t=10, alpha=1.0, a=0.5)
    # u = (1*1 + 0.30*-1)/1.30 ~ 0.536; alpha_t = (5+1)^-0.5 ~ 0.408
    assert float(out["w"][0]) > 0.15


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=2, max_size=8),
       st.floats(0.1, 1.0))
def test_aggregation_is_convex_combination(stalenesses, alpha):
    """Property: the new global model is a convex combination of the old
    model and the cached updates -> stays inside their value hull."""
    rng = np.random.RandomState(42)
    updates = [{"w": jnp.asarray(rng.uniform(-1, 1, 4).astype(np.float32))}
               for _ in stalenesses]
    w0 = {"w": jnp.asarray(rng.uniform(-1, 1, 4).astype(np.float32))}
    cache = [(u, int(max(stalenesses) - s), 10) for u, s in
             zip(updates, stalenesses)]
    out = aggregate_cache(w0, cache, t=int(max(stalenesses)), alpha=alpha)
    lo = np.minimum.reduce([np.asarray(u["w"]) for u in updates]
                           + [np.asarray(w0["w"])])
    hi = np.maximum.reduce([np.asarray(u["w"]) for u in updates]
                           + [np.asarray(w0["w"])])
    v = np.asarray(out["w"])
    assert (v >= lo - 1e-5).all() and (v <= hi + 1e-5).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 50), st.floats(0.05, 2.0))
def test_staleness_weight_properties(s, a):
    """S is in (0,1], monotone decreasing in staleness."""
    w1 = float(staleness_weight(s, a))
    w2 = float(staleness_weight(s + 1, a))
    assert 0 < w2 < w1 <= 1.0
