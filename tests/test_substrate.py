"""Substrate tests: optimizers, data partitioners, checkpointing, sharding
rules, latency model."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_pytree, save_pytree
from repro.core.latency import WirelessConfig, comm_latency, device_rates
from repro.data import (make_fmnist_like, partition_dirichlet, partition_iid,
                        partition_noniid_classes)
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.sharding.rules import Rules, logical_axes_for


# -- optimizers -----------------------------------------------------------
def _rosenbrock_ish(params):
    return jnp.sum((params["a"] - 1.0) ** 2) + jnp.sum(params["b"] ** 2)


def test_sgd_and_adamw_converge():
    for opt in (sgd(0.1, momentum=0.9), adamw(0.1)):
        params = {"a": jnp.zeros(3), "b": jnp.ones(2)}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(_rosenbrock_ish)(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(_rosenbrock_ish(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


# -- data -----------------------------------------------------------------
def test_fmnist_like_is_learnable_and_separable():
    d = make_fmnist_like(2000, 500, seed=0)
    assert d["x_train"].shape == (2000, 28, 28, 1)
    # nearest-class-mean classifier must beat chance by a wide margin
    means = np.stack([d["x_train"][d["y_train"] == c].mean(0).ravel()
                      for c in range(10)])
    xt = d["x_test"].reshape(len(d["y_test"]), -1)
    pred = np.argmin(((xt[:, None] - means[None]) ** 2).sum(-1), axis=1)
    acc = (pred == d["y_test"]).mean()
    assert acc > 0.3, acc


def test_partitions_cover_and_disjoint_iid():
    parts = partition_iid(1000, 10, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 1000 and len(set(allidx.tolist())) == 1000


def test_noniid_two_class_property():
    d = make_fmnist_like(5000, 100, seed=1)
    parts = partition_noniid_classes(d["y_train"], 20, 2, seed=1)
    for p in parts:
        classes = set(d["y_train"][p].tolist())
        assert len(classes) <= 2


@settings(max_examples=10, deadline=None)
@given(st.floats(0.05, 5.0))
def test_dirichlet_partition_valid(alpha):
    labels = np.random.RandomState(0).randint(0, 10, 2000)
    parts = partition_dirichlet(labels, 8, alpha, seed=3)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(set(allidx.tolist()))


# -- checkpoint -----------------------------------------------------------
def test_checkpoint_roundtrip():
    tree = {"layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save_pytree(path, tree)
        out = load_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]),
                                  np.asarray(tree["layers"]["w"]))
    assert int(out["step"]) == 7


# -- sharding rules --------------------------------------------------------
def test_spec_drops_nondivisible_axes():
    import jax as _jax
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    rules = Rules(mesh, mapping={"heads": "model"})
    # 9 heads on 1-way model axis: divisible, kept
    assert rules.spec(("batch", "heads"), (4, 9))[1] == "model"

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}
    r2 = Rules.__new__(Rules)
    r2.mesh = FakeMesh()
    r2.mapping = dict({"batch": "data", "heads": "model"})
    spec = r2.spec(("batch", "heads"), (8, 9))
    assert spec[1] is None          # 9 % 16 != 0 -> dropped
    spec2 = r2.spec(("batch", "heads"), (8, 48))
    assert spec2[1] == "model"


def test_logical_axes_for_param_names():
    assert logical_axes_for("layers/attn/wq", 3)[0] == "stack"
    assert logical_axes_for("layers/moe/e_gate", 4) == \
        ("stack", "experts", None, None)
    assert logical_axes_for("embed", 2) == ("vocab", "d_model")


# -- latency model ----------------------------------------------------------
def test_wireless_rates_monotone_in_radius():
    rng = np.random.RandomState(0)
    near = device_rates(500, WirelessConfig(radius_m=100.0), rng)[1].mean()
    far = device_rates(500, WirelessConfig(radius_m=1000.0),
                       np.random.RandomState(0))[1].mean()
    assert near > far


def test_comm_latency_scales_with_bytes():
    assert comm_latency(2e6, 1e6) == 2.0
    assert comm_latency(1e6, 1e6) < comm_latency(4e6, 1e6)
