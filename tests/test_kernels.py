"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles.

Kernels run in interpret=True mode (CPU container); bodies are the same code
that lowers to TPU pallas_call + BlockSpec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_pack import fused_pack_leaf, pack_leaves_host
from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_intra_chunk
from repro.kernels.topk_quant import dequant, topk_quant
from repro.models.ssm import ssd_chunked


# ----------------------------------------------------------------------
# topk_quant
# ----------------------------------------------------------------------
@pytest.mark.parametrize("block", [256, 1024, 4096])
@pytest.mark.parametrize("p_s", [0.05, 0.25, 0.5])
@pytest.mark.parametrize("bits", [8, 4])
def test_topk_quant_matches_oracle(block, p_s, bits):
    rng = np.random.RandomState(hash((block, int(p_s * 100), bits)) % 2**31)
    x = jnp.asarray(rng.randn(4 * block).astype(np.float32))
    lv, sc = topk_quant(x, p_s=p_s, bits=bits, block=block)
    lv_ref, sc_ref = ref.topk_quant_ref(x.reshape(4, block), p_s, bits)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_ref))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_ref), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_quant_dtypes(dtype):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2048).astype(np.float32)).astype(dtype)
    lv, sc = topk_quant(x, p_s=0.25, bits=8, block=1024)
    assert lv.dtype == jnp.int8 and sc.dtype == jnp.float32
    kept = float((lv != 0).mean())
    assert abs(kept - 0.25) < 0.05


def test_topk_quant_keep_fraction_accuracy():
    """Binary-search threshold keeps ~p_s of entries (within 2^-16 + ties)."""
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(65536).astype(np.float32))
    for p_s in (0.01, 0.1, 0.33):
        lv, _ = topk_quant(x, p_s=p_s, bits=8, block=16384)
        kept = float((lv != 0).mean())
        assert abs(kept - p_s) < 0.02, (p_s, kept)


def test_topk_quant_padding():
    """Non-multiple-of-block sizes are zero-padded, zeros stay zero."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(1500).astype(np.float32))
    lv, sc = topk_quant(x, p_s=0.5, bits=8, block=1024)
    y = dequant(lv, sc, 8, 1500, (1500,))
    assert y.shape == (1500,)
    # top values survive the round trip with quantization error only
    idx = np.argsort(-np.abs(np.asarray(x)))[:100]
    scale = np.abs(np.asarray(x)).max()
    np.testing.assert_allclose(np.asarray(y)[idx], np.asarray(x)[idx],
                               atol=scale / 127 * 1.01)


def test_block_topk_vs_global_topk_error_bounded():
    """Block-local Top-K (TPU adaptation) approximates global Top-K: the kept
    mass is close to the globally-optimal kept mass."""
    rng = np.random.RandomState(4)
    x = rng.randn(8, 4096).astype(np.float32) * rng.uniform(0.5, 2.0, (8, 1))
    flat = jnp.asarray(x.reshape(-1))
    lv, sc = topk_quant(flat, p_s=0.25, bits=32 if False else 8, block=4096)
    y = np.asarray(dequant(lv, sc, 8, flat.size, (flat.size,)))
    kept_mass = np.abs(y).sum()
    k = int(0.25 * flat.size)
    global_mass = np.sort(np.abs(x.reshape(-1)))[-k:].sum()
    assert kept_mass >= 0.85 * global_mass


# ----------------------------------------------------------------------
# fused_pack: the one-pass sparsify+quantize+pack emitter.  Always-run
# deterministic grid (the hypothesis suite lives in tests/test_fused_pack);
# interpret mode exercises the exact body that lowers to TPU pallas_call.
# ----------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("n", [1, 7, 100, 1500, 4097])
@pytest.mark.parametrize("p_s", [0.05, 0.25, 1.0])
@pytest.mark.parametrize("p_q", [2, 8, 32])
def test_fused_pack_kernel_matches_host_twin(n, p_s, p_q):
    """Kernel stream == numpy-twin stream, bit for bit, across odd sizes,
    the k==n dense fallback (p_s=1.0) and raw-f32 values (p_q=32)."""
    rng = np.random.RandomState(hash((n, int(p_s * 100), p_q)) % 2**31)
    x = rng.randn(n).astype(np.float32)
    payload, nbits = fused_pack_leaf(x, p_s, p_q, interpret=True)
    assert payload == pack_leaves_host([x], p_s, p_q)
    assert len(payload) == (nbits + 7) // 8


@pytest.mark.smoke
def test_fused_pack_kernel_tie_and_zero_regimes():
    """Degenerate magnitudes: all-zero tensors (threshold 0, scale floor)
    and heavily-tied data must still match the host twin exactly."""
    for x in (np.zeros(300, np.float32),
              np.tile(np.float32([0.5, -0.5, 0.0]), 100),
              np.full(129, -0.25, np.float32)):
        for p_s in (0.1, 0.5):
            payload, _ = fused_pack_leaf(x, p_s, 8, interpret=True)
            assert payload == pack_leaves_host([x], p_s, 8)


# ----------------------------------------------------------------------
# ssd_scan
# ----------------------------------------------------------------------
def _ssd_inputs(B, S, H, P, N, seed=0):
    rng = np.random.RandomState(seed)
    xh = jnp.asarray(rng.randn(B, S, H, P).astype(np.float32))
    b = jnp.asarray(rng.randn(B, S, N).astype(np.float32)) * 0.3
    c = jnp.asarray(rng.randn(B, S, N).astype(np.float32)) * 0.3
    dt = jnp.abs(jnp.asarray(rng.randn(B, S, H).astype(np.float32))) * 0.1
    la = -jnp.abs(jnp.asarray(rng.randn(B, S, H).astype(np.float32))) * 0.05
    return xh, b, c, dt, la


@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize("N", [16, 32, 128])
def test_ssd_kernel_matches_model(chunk, N):
    xh, b, c, dt, la = _ssd_inputs(2, 256, 2, 64, N)
    y_ref, h_ref = ssd_chunked(xh, b, c, dt, la, chunk)
    y_k, h_k = ssd_chunked_pallas(xh, b, c, dt, la, chunk)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_k),
                               atol=1e-5, rtol=1e-5)


def test_ssd_intra_chunk_matches_chunk_oracle():
    rng = np.random.RandomState(7)
    L, P, N = 64, 32, 16
    xb = jnp.asarray(rng.randn(3, L, P).astype(np.float32))
    b = jnp.asarray(rng.randn(3, L, N).astype(np.float32))
    c = jnp.asarray(rng.randn(3, L, N).astype(np.float32))
    cum = jnp.cumsum(-jnp.abs(jnp.asarray(
        rng.randn(3, L).astype(np.float32))) * 0.1, axis=1)
    y, s, a = ssd_intra_chunk(xb, b, c, cum[:, None, :])
    for g in range(3):
        y_r, s_r, a_r = ref.ssd_chunk_ref(xb[g], b[g], c[g], cum[g])
        np.testing.assert_allclose(np.asarray(y[g]), np.asarray(y_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s[g]), np.asarray(s_r).T
                                   if s_r.shape != s[g].shape else
                                   np.asarray(s_r), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(float(a[g, 0]), float(a_r), rtol=1e-6)


def test_ssd_chunk_size_invariance():
    """SSD output must not depend on the chunking."""
    xh, b, c, dt, la = _ssd_inputs(1, 128, 2, 64, 32, seed=9)
    y1, h1 = ssd_chunked_pallas(xh, b, c, dt, la, 32)
    y2, h2 = ssd_chunked_pallas(xh, b, c, dt, la, 128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-5, rtol=2e-5)


def test_ssd_kernel_bf16():
    xh, b, c, dt, la = _ssd_inputs(1, 128, 2, 64, 32, seed=11)
    y32, _ = ssd_chunked_pallas(xh, b, c, dt, la, 64)
    y16, _ = ssd_chunked_pallas(xh.astype(jnp.bfloat16), b, c, dt, la, 64)
    assert y16.dtype == jnp.bfloat16
    rel = float(jnp.abs(y32 - y16.astype(jnp.float32)).max()
                / (jnp.abs(y32).max() + 1e-9))
    assert rel < 0.05
