"""Hypothesis property suite for the fused wire emitter (skipped without
hypothesis, like tests/test_codecs.py; the always-running deterministic pins
live in tests/test_fused_pack.py and tests/test_kernels.py).

Properties: fused-vs-oracle stream bit-equality over adversarial shapes
(n=1 scalars, the k==n dense fallback, the uncompressed p_q=32 point,
tie-heavy magnitudes straddling the k-th place), word-level
pack_segments/BitReader identity for widths 1-32 with odd/empty segments,
and per-leaf kernel slices re-joined by concat_bitstreams equalling the
one-pass tree twin.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.codecs import PackedBitstreamCodec
from repro.core.compression import expected_pytree_wire_bytes
from repro.kernels.bitpack import BitReader, pack_segments
from repro.kernels.fused_pack import (concat_bitstreams, fused_pack_leaf,
                                      pack_leaves_host)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 700),
       p_s=st.sampled_from([0.01, 0.05, 0.1, 0.25, 0.5, 0.9, 1.0]),
       p_q=st.sampled_from([2, 3, 4, 7, 8, 13, 16, 31, 32]),
       tie_heavy=st.booleans())
def test_fused_stream_equals_oracle_stream(seed, n, p_s, p_q, tie_heavy):
    rng = np.random.RandomState(seed)
    if tie_heavy:
        flat = rng.choice([0.0, 0.125, -0.125, 1.0, -1.0], size=n)
    else:
        flat = rng.randn(n)
    tree = [flat.astype(np.float32)]
    oracle = PackedBitstreamCodec(p_s, p_q, fused=False).encode(tree)
    fused = PackedBitstreamCodec(p_s, p_q, fused=True).encode(tree)
    assert fused.payload == oracle.payload
    assert fused.nbytes == oracle.nbytes == len(oracle.payload)
    if p_s < 1.0 or p_q < 32:   # dense point: analytic price excludes scales
        assert len(fused.payload) == expected_pytree_wire_bytes(tree, p_s, p_q)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000),
       widths=st.lists(st.integers(1, 32), min_size=1, max_size=6),
       counts=st.lists(st.integers(0, 40), min_size=6, max_size=6))
def test_word_level_pack_read_roundtrip(seed, widths, counts):
    rng = np.random.RandomState(seed)
    segs = [(rng.randint(0, 2 ** w, size=c, dtype=np.int64).astype(np.uint32), w)
            for w, c in zip(widths, counts[:len(widths)])]
    payload = pack_segments(segs)
    total_bits = sum(v.size * w for v, w in segs)
    assert len(payload) == (total_bits + 7) // 8
    reader = BitReader(payload)
    for v, w in segs:
        np.testing.assert_array_equal(reader.read(v.size, w), v)
    assert reader.bits_read == total_bits


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000),
       sizes=st.lists(st.integers(1, 300), min_size=1, max_size=5),
       p_s=st.sampled_from([0.05, 0.25, 0.5]),
       p_q=st.sampled_from([2, 8, 16]))
def test_per_leaf_kernel_concat_equals_tree_twin(seed, sizes, p_s, p_q):
    rng = np.random.RandomState(seed)
    leaves = [rng.randn(s).astype(np.float32) for s in sizes]
    parts = [fused_pack_leaf(x, p_s, p_q, interpret=True) for x in leaves]
    assert concat_bitstreams(parts) == pack_leaves_host(leaves, p_s, p_q)
