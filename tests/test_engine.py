"""FLEngine behaviour tests: strategy registry, vectorized cohort execution,
scenario injection (dropout / transient failure / tiers), and event-loop
edge cases.  Bit-parity against the legacy simulator lives in
tests/test_engine_parity.py."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import expected_pytree_wire_bytes
from repro.fl.engine import FLEngine, _cohort_round
from repro.fl.protocols import (METHODS, STRATEGIES, make_setup, make_sim,
                                make_strategy, run_method)
from repro.fl.simulator import (FLSimulator, ScenarioConfig, SimConfig,
                                TierSpec)
from repro.models.cnn import cnn_cohort_loss, cnn_loss, init_cnn


# ----------------------------------------------------------------------
# strategy registry (pure, fast)
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_registry_covers_all_methods():
    assert set(STRATEGIES) == set(METHODS)
    cfg = SimConfig(n_devices=4)
    for m in METHODS:
        s = make_strategy(m, cfg)
        assert s.method == m


@pytest.mark.smoke
def test_make_strategy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown method"):
        make_strategy("fedsgd", SimConfig(n_devices=4))


@pytest.mark.smoke
def test_compression_per_strategy():
    cfg = SimConfig(n_devices=4, p_s=0.25, p_q=8)
    assert make_strategy("tea", cfg).compression_at(0) == (1.0, 32)
    assert make_strategy("fedasync", cfg).compression_at(0) == (1.0, 32)
    assert make_strategy("teas", cfg).compression_at(0) == (0.25, 32)
    assert make_strategy("teaq", cfg).compression_at(0) == (1.0, 8)
    assert make_strategy("teastatic", cfg).compression_at(0) == (0.25, 8)
    assert make_strategy("teasq", cfg).compression_at(0) == (0.25, 8)


@pytest.mark.smoke
def test_async_mixing_weights_decay_with_staleness():
    cfg = SimConfig(n_devices=4, alpha=0.6)
    for m in ("fedasync", "port", "asofed"):
        s = make_strategy(m, cfg)
        ws = [s.mixing_weight(k) for k in range(6)]
        assert ws[0] == pytest.approx(0.6)
        assert all(a >= b for a, b in zip(ws, ws[1:])), m


# ----------------------------------------------------------------------
# event-loop edge cases (incl. the legacy `now`-unbound regression)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_setup():
    return make_setup(n_devices=12, iid=True, seed=1, n_train=480, n_test=240)


@pytest.mark.smoke
def test_empty_fleet_does_not_crash(tiny_setup):
    """Regression: _run_async referenced `now` before assignment when the
    event heap never produced an in-budget event."""
    data, _, w0 = tiny_setup
    for cls in (FLSimulator, FLEngine):
        sim = cls(data, [], w0, SimConfig(method="tea", n_devices=0, seed=0))
        hist = sim.run(time_budget=5.0)
        assert len(hist) == 2 and hist[-1].round == 0


@pytest.mark.smoke
def test_zero_budget_does_not_crash(tiny_setup):
    data, parts, w0 = tiny_setup
    for backend in ("legacy", "engine"):
        hist = run_method("tea", data, parts, w0, time_budget=0.0,
                          epochs=1, backend=backend)
        assert hist[-1].round == 0
        assert hist[-1].time <= 0.0


# ----------------------------------------------------------------------
# vectorized cohort path
# ----------------------------------------------------------------------
def test_cohort_round_matches_serial_prox_sgd():
    """One device, no compression: the fused cohort kernel must match a
    hand-rolled prox-SGD loop with the same minibatch order."""
    rng = np.random.RandomState(0)
    w0 = init_cnn(jax.random.PRNGKey(0))
    n, bs, steps = 24, 8, 3
    x = rng.randn(n, 28, 28, 1).astype(np.float32)
    y = rng.randint(0, 10, n).astype(np.int32)
    bidx = rng.permutation(n).reshape(steps, bs).astype(np.int32)
    lr, mu = 0.08, 0.01

    # reference: the serial per-batch update of core.client.local_update
    params = w0
    for t in range(steps):
        batch = {"images": jnp.asarray(x[bidx[t]]),
                 "labels": jnp.asarray(y[bidx[t]])}
        grads = jax.grad(cnn_loss)(params, batch)
        params = jax.tree.map(lambda p, g, a: p - lr * (g + mu * (p - a)),
                              params, grads, w0)

    w_up = _cohort_round(
        jax.tree.map(lambda a: a[None], w0),          # one version
        jnp.zeros(1, jnp.int32), jnp.asarray(x[None]), jnp.asarray(y[None]),
        jnp.zeros(1, jnp.int32), jnp.asarray(bidx[:, None, :]),
        jnp.ones((steps, 1), jnp.float32),
        cohort_loss=cnn_cohort_loss, lr=lr, mu=mu, p_s=1.0, p_q=32, iters=8)
    for leaf_ref, leaf_vec in zip(jax.tree.leaves(params),
                                  jax.tree.leaves(w_up)):
        np.testing.assert_allclose(np.asarray(leaf_ref),
                                   np.asarray(leaf_vec)[0],
                                   rtol=2e-5, atol=2e-6)


def test_cohort_engine_runs_and_accounts_bytes(tiny_setup):
    data, parts, w0 = tiny_setup
    cfg = SimConfig(method="teastatic", n_devices=len(parts), p_s=0.5,
                    p_q=8, epochs=1, batch_size=8, seed=1, c_fraction=0.5,
                    gamma=0.25, cohort_size=4)
    eng = make_sim(data, parts, w0, cfg)
    hist = eng.run(time_budget=4.0, eval_every=2)
    assert hist[-1].round >= 1
    assert np.isfinite(hist[-1].accuracy)
    st = eng.stats
    assert st.completions > 0
    # every completed arrival was trained through a flush
    assert st.flushed_tasks >= st.completions
    assert st.flushes >= 1
    # byte accounting: every dispatched task pays exactly the deterministic
    # packed wire size, both directions
    per_task = expected_pytree_wire_bytes(w0, 0.5, 8)
    assert hist[-1].bytes_up % per_task == 0
    assert hist[-1].bytes_up // per_task >= st.completions
    assert hist[-1].bytes_up == hist[-1].bytes_down
    assert hist[-1].max_model_bytes_up == per_task


def test_cohort_and_serial_reach_similar_round_counts(tiny_setup):
    """Deferred execution changes RNG draw order, not protocol dynamics:
    round counts over the same budget should be in the same ballpark."""
    data, parts, w0 = tiny_setup
    kw = dict(time_budget=4.0, epochs=1, batch_size=8, c_fraction=0.5,
              gamma=0.25, eval_every=10 ** 9, backend="engine")
    h_serial = run_method("tea", data, parts, w0, **kw)
    h_cohort = run_method("tea", data, parts, w0, cohort_size=4, **kw)
    r_s, r_c = h_serial[-1].round, h_cohort[-1].round
    assert r_c >= 1
    assert 0.5 * r_s <= r_c <= 2.0 * r_s + 1


# ----------------------------------------------------------------------
# scenario injection
# ----------------------------------------------------------------------
def _scenario_engine(tiny_setup, scenario, **cfg_kw):
    data, parts, w0 = tiny_setup
    cfg = SimConfig(method="tea", n_devices=len(parts), epochs=1,
                    batch_size=8, seed=1, c_fraction=0.5, gamma=0.25,
                    scenario=scenario, **cfg_kw)
    return make_sim(data, parts, w0, cfg)


def test_scenario_inactive_is_bit_identical(tiny_setup):
    """An all-zero ScenarioConfig must not perturb the event stream."""
    data, parts, w0 = tiny_setup
    h_none = run_method("tea", data, parts, w0, time_budget=3.0, epochs=1,
                        backend="engine")
    h_zero = run_method("tea", data, parts, w0, time_budget=3.0, epochs=1,
                        backend="engine", scenario=ScenarioConfig())
    assert h_none == h_zero


def test_scenario_dropout_removes_devices(tiny_setup):
    eng = _scenario_engine(tiny_setup, ScenarioConfig(dropout_prob=0.5))
    hist = eng.run(time_budget=6.0, eval_every=10 ** 9)
    st = eng.stats
    assert st.dropouts > 0
    assert int(eng.devices.alive.sum()) == len(eng.partitions) - st.dropouts
    # dead devices stop training, the rest keep the protocol alive
    assert st.completions > 0 and hist[-1].round >= 1
    dead = ~eng.devices.alive
    assert st.completed_per_device is not None
    # a dropped device never completes an upload after its failure, so its
    # completion count stays below the busiest survivor's
    if dead.any() and (~dead).any():
        assert (st.completed_per_device[dead].min()
                <= st.completed_per_device[~dead].max())


def test_scenario_transient_failures_retry(tiny_setup):
    eng = _scenario_engine(tiny_setup, ScenarioConfig(failure_prob=0.4,
                                                      retry_backoff=0.1))
    hist = eng.run(time_budget=6.0, eval_every=10 ** 9)
    st = eng.stats
    assert st.transient_failures > 0
    assert st.dropouts == 0
    assert int(eng.devices.alive.sum()) == len(eng.partitions)
    assert st.completions > 0 and hist[-1].round >= 1


def test_scenario_tiers_skew_completions(tiny_setup):
    fast = TierSpec(0.5, compute_scale=0.2, bandwidth_scale=5.0, name="fast")
    slow = TierSpec(0.5, compute_scale=5.0, bandwidth_scale=0.2, name="slow")
    eng = _scenario_engine(tiny_setup, ScenarioConfig(tiers=[fast, slow]))
    eng.run(time_budget=6.0, eval_every=10 ** 9)
    n = len(eng.partitions)
    assert list(eng.devices.tier) == [0] * (n // 2) + [1] * (n - n // 2)
    done = eng.stats.completed_per_device
    assert done[:n // 2].sum() > done[n // 2:].sum()


# ----------------------------------------------------------------------
# opt-in wall-clock race (the ISSUE-1 scale acceptance, shrunk)
# ----------------------------------------------------------------------
@pytest.mark.scale
def test_vectorized_1000_devices_beats_legacy_100():
    """1000-device TEASQ on the cohort path must complete a 30 s virtual
    budget in less wall-clock than the legacy loop at 100 devices (~14x
    fewer protocol tasks).  Wall-clock sensitive: opt in with -m scale;
    `python -m benchmarks.engine_scale` is the logged demonstration."""
    from benchmarks.engine_scale import run_one
    from repro.data.synthetic import make_fmnist_like
    data = make_fmnist_like(12000, 1000, seed=0)
    legacy = run_one(data, 12000, 100, "legacy", 0, budget=30.0)
    vec = run_one(data, 12000, 1000, "engine", 32, budget=30.0)
    assert vec["tasks"] > 5 * legacy["rounds"]       # far more protocol work
    assert vec["wall_s"] < legacy["wall_s"], (vec, legacy)
