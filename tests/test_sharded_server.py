"""Sharded staleness-weighted aggregation (``SERVERS["sharded"]``).

Parity contract under test, layer by layer:

* ``aggregate_cache_sharded_ref`` (the mesh-free column-block reference)
  computes the SAME per-element program as the single-host stacked kernel
  — weights and the mixing factor are recomputed identically inside every
  block — so it must match ``aggregate_cache_stacked`` to <= 1 ulp.  The
  observed difference is 0 ulp on this container; the 1-ulp allowance
  only covers XLA re-fusing the identical scalar program differently
  across compiler versions, not any real reassociation.
* Against the *serial* K-tuple kernel (``aggregate_cache``) the stacked
  reduction legitimately reassociates (tensordot vs sequential adds), so
  the comparison is allclose — the same tolerance the wave-mode
  ``receive_many`` unit test uses.
* ``ShardedTeasqServer`` on ONE device builds no mesh and delegates to
  the parent's exact kernels, so ``server="sharded"`` on a single-device
  process replays the pinned history fixture bit-for-bit.
* On a real multi-device host mesh (``--xla_force_host_platform_
  device_count``, set before jax init, hence the subprocess) the
  ``shard_map`` path must hold the same <= 1-ulp bound against the
  stacked kernel across mesh sizes {1, 2, 4}, and end-to-end engine runs
  with ``server="sharded"`` must keep the event timeline (rounds, times,
  byte meters) exactly while weights stay allclose.
"""
import dataclasses
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import (PINNED_PATH, TINY_SETUP, assert_histories_equal,
                      run_tiny)
from repro.core.server import (SERVERS, ServerConfig, ShardedTeasqServer,
                               TeasqServer, make_server)
from repro.core.staleness import (aggregate_cache, aggregate_cache_sharded_ref,
                                  aggregate_cache_stacked)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # the fixed grid below still pins the parity
    HAVE_HYPOTHESIS = False


def max_ulp_diff(a, b):
    """Largest per-element distance in float32 units-in-the-last-place.

    Bit patterns are mapped to a monotonic integer ordering of the reals
    (negative floats mirrored below zero, -0.0 == +0.0), so adjacent
    representable floats differ by exactly 1 and the comparison is scale-
    free — unlike an epsilon, 1 ulp means "the same computation modulo
    one final rounding", which is the strongest cross-compiler statement
    short of bit equality."""
    ia = np.asarray(a, np.float32).ravel().view(np.int32).astype(np.int64)
    ib = np.asarray(b, np.float32).ravel().view(np.int32).astype(np.int64)
    la = np.where(ia >= 0, ia, np.int64(-2 ** 31) - ia)
    lb = np.where(ib >= 0, ib, np.int64(-2 ** 31) - ib)
    return int(np.abs(la - lb).max()) if la.size else 0


def _tree_ulp(t_a, t_b):
    return max(max_ulp_diff(a, b) for a, b in
               zip(jax.tree.leaves(t_a), jax.tree.leaves(t_b)))


def _rand_tree(rng, shapes=((13, 7), (5,))):
    return {f"l{i}": rng.randn(*sh).astype(np.float32)
            for i, sh in enumerate(shapes)}


def _rand_cache(rng, size, shapes=((13, 7), (5,))):
    return [(_rand_tree(rng, shapes), int(rng.randint(0, 5)),
             int(rng.randint(1, 200))) for _ in range(size)]


# ----------------------------------------------------------------------
# registry + construction
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_servers_registry():
    assert SERVERS["single"] is TeasqServer
    assert SERVERS["sharded"] is ShardedTeasqServer
    cfg = ServerConfig(n_devices=10)
    w0 = {"w": np.zeros(3, np.float32)}
    assert type(make_server("single", w0, cfg)) is TeasqServer
    srv = make_server("sharded", w0, cfg, shards=1)
    assert type(srv) is ShardedTeasqServer
    with pytest.raises(ValueError, match="unknown server"):
        make_server("bogus", w0, cfg)


@pytest.mark.smoke
def test_degenerate_sharded_has_no_mesh():
    """shards=1 (or a single-device process) must build no mesh and route
    both aggregation hooks to the parent's exact kernels."""
    srv = make_server("sharded", {"w": np.zeros(3, np.float32)},
                      ServerConfig(n_devices=10), shards=1)
    assert srv.n_shards == 1
    assert srv.mesh is None and srv._agg is None


def test_engine_rejects_unknown_server(tiny_setup):
    from repro.fl.protocols import make_sim
    from repro.fl.simulator import SimConfig
    data, parts, w0 = tiny_setup
    cfg = SimConfig(n_devices=len(parts), server="bogus")
    with pytest.raises(ValueError, match="unknown server"):
        make_sim(data, parts, w0, cfg)


# ----------------------------------------------------------------------
# kernel parity: mesh-free column-block reference vs the pinned kernels
# ----------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("cache_size", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_sharded_ref_matches_stacked_kernel(cache_size, n_shards):
    """Column-block sharding vs the single-host stacked kernel: <= 1 ulp
    (0 observed — see the module docstring), every (cache, mesh) size
    including ones that force zero-padding of the flat vector."""
    rng = np.random.RandomState(cache_size * 10 + n_shards)
    w0 = _rand_tree(rng)
    cache = _rand_cache(rng, cache_size)
    want = aggregate_cache_stacked(w0, cache, t=6, alpha=0.6, a=0.5)
    got = aggregate_cache_sharded_ref(w0, cache, t=6, alpha=0.6, a=0.5,
                                      n_shards=n_shards)
    assert _tree_ulp(got, want) <= 1


@pytest.mark.smoke
def test_sharded_ref_close_to_serial_kernel():
    """Against the serial K-tuple kernel the permitted divergence is the
    stacked tensordot reassociation — allclose at the receive_many
    tolerance."""
    rng = np.random.RandomState(0)
    w0 = _rand_tree(rng)
    cache = _rand_cache(rng, 4)
    a = aggregate_cache(w0, cache, t=6, alpha=0.6, a=0.5)
    b = aggregate_cache_sharded_ref(w0, cache, t=6, alpha=0.6, a=0.5,
                                    n_shards=3)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data(),
           cache_size=st.integers(min_value=1, max_value=8),
           n_shards=st.integers(min_value=1, max_value=4),
           t=st.integers(min_value=0, max_value=30),
           alpha=st.floats(min_value=0.1, max_value=1.0),
           seed=st.integers(min_value=0, max_value=99))
    def test_sharded_ref_property(data, cache_size, n_shards, t, alpha,
                                  seed):
        """Property form of the grid: hypothesis explores cache sizes,
        staleness vectors, leaf shapes (odd sizes exercise the padding
        path) and mesh widths; a violation shrinks to a minimal cache."""
        rng = np.random.RandomState(seed)
        shapes = ((data.draw(st.integers(1, 9), label="rows"),
                   data.draw(st.integers(1, 9), label="cols")),
                  (data.draw(st.integers(1, 7), label="bias"),))
        w0 = _rand_tree(rng, shapes)
        cache = [(_rand_tree(rng, shapes),
                  data.draw(st.integers(0, t), label=f"h{i}"),
                  data.draw(st.integers(1, 500), label=f"n{i}"))
                 for i in range(cache_size)]
        want = aggregate_cache_stacked(w0, cache, t=t, alpha=alpha, a=0.5)
        got = aggregate_cache_sharded_ref(w0, cache, t=t, alpha=alpha,
                                          a=0.5, n_shards=n_shards)
        assert _tree_ulp(got, want) <= 1


# ----------------------------------------------------------------------
# degenerate mesh: server="sharded" on one device is the pinned machine
# ----------------------------------------------------------------------
_single_device = pytest.mark.skipif(
    len(jax.devices()) > 1,
    reason="degenerate-mesh bit-parity needs a single-device process")


@_single_device
@pytest.mark.parametrize("method", ["teasq", "fedasync"])
def test_engine_degenerate_sharded_bit_identical(method, tiny_setup):
    """End-to-end: the engine with ``server="sharded"`` on one device must
    replay the default server's history bit-for-bit (no mesh -> parent
    kernels)."""
    h_single = run_tiny(method, tiny_setup)
    h_sharded = run_tiny(method, tiny_setup, server="sharded")
    assert_histories_equal(h_single, h_sharded)


@_single_device
def test_degenerate_sharded_repins_fixture(tiny_setup):
    """Directly against the recorded fixture: the sharded backend on one
    device stays on the pinned-history manifold."""
    with open(PINNED_PATH) as f:
        pinned = json.load(f)
    assert pinned["setup"] == TINY_SETUP
    kw = pinned["runs_batched"]["teasq"]
    hist = run_tiny("teasq", tiny_setup, task="fmnist_cnn",
                    **pinned["run_kw"],
                    **{**kw, "scheduler": "batched", "server": "sharded"})
    got = [dataclasses.asdict(h) for h in hist]
    assert got == pinned["histories_batched"]["teasq"]


# ----------------------------------------------------------------------
# real host mesh: shard_map parity across mesh sizes + protocols
# ----------------------------------------------------------------------
MESH_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core.server import ServerConfig, TeasqServer, make_server
from repro.fl.protocols import make_setup, run_method

assert len(jax.devices()) == 4, jax.devices()

def ulp(t_a, t_b):
    worst = 0
    for a, b in zip(jax.tree.leaves(t_a), jax.tree.leaves(t_b)):
        ia = np.asarray(a, np.float32).ravel().view(np.int32).astype(np.int64)
        ib = np.asarray(b, np.float32).ravel().view(np.int32).astype(np.int64)
        la = np.where(ia >= 0, ia, np.int64(-2 ** 31) - ia)
        lb = np.where(ib >= 0, ib, np.int64(-2 ** 31) - ib)
        worst = max(worst, int(np.abs(la - lb).max()))
    return worst

rng = np.random.RandomState(0)
def tree():
    return {"w1": rng.randn(13, 7).astype(np.float32),
            "b": rng.randn(5).astype(np.float32)}
def copy(t):
    return {k: v.copy() for k, v in t.items()}

# server-level parity: identical entry streams through every mesh width,
# both receive paths, vs single-host servers
cfg = ServerConfig(n_devices=10, gamma=0.3)          # K = 3
w0 = tree()
entries = [(tree(), max(0, i % 4 - 1), 10 + 3 * i) for i in range(8)]
for mesh in (1, 2, 4):
    for wave in (False, True):
        srv = make_server("sharded", copy(w0), cfg, shards=mesh)
        assert srv.n_shards == mesh
        ref = TeasqServer(copy(w0), cfg)              # single-host control
        srv.active = ref.active = len(entries)
        if wave:
            done_s = srv.receive_many(list(entries))
            done_r = ref.receive_many(list(entries))
        else:
            done_s = [srv.receive(*e) for e in entries]
            done_r = [ref.receive(*e) for e in entries]
        assert done_s == done_r and srv.t == ref.t
        if mesh == 1:
            # degenerate: parent kernels, bit-identical on both paths
            assert ulp(srv.w, ref.w) == 0, (wave, ulp(srv.w, ref.w))
        elif wave:
            # flat sharded kernel vs the stacked kernel: same per-element
            # program -> <= 1 ulp (0 observed)
            assert ulp(srv.w, ref.w) <= 1, ulp(srv.w, ref.w)
        else:
            # serial control used the K-tuple kernel: reassociation only
            for a, b in zip(jax.tree.leaves(srv.w), jax.tree.leaves(ref.w)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)
            # vs a stacked-kernel control fed the same stream: <= 1 ulp
            ctl = TeasqServer(copy(w0), cfg)
            ctl.active = len(entries)
            ctl.receive_many(list(entries))
            assert ulp(srv.w, ctl.w) <= 1, ulp(srv.w, ctl.w)
print("SERVER-PARITY OK")

# engine-level: full runs per protocol — the event timeline (rounds,
# times, byte meters) must not move when the aggregation is sharded;
# weights/accuracy may differ by the kernel reassociation only
data, parts, w0 = make_setup(n_devices=8, iid=True, seed=3, n_train=320,
                             n_test=160)
for method in ("teasq", "fedasync"):
    runs = {}
    for server in ("single", "sharded"):
        runs[server] = run_method(method, data, parts, w0, time_budget=2.0,
                                  seed=3, epochs=1, server=server,
                                  server_shards=4)
    h_a, h_b = runs["single"], runs["sharded"]
    assert len(h_a) == len(h_b) and len(h_a) >= 2, (method, len(h_a))
    for a, b in zip(h_a, h_b):
        assert (a.time, a.round, a.bytes_up, a.bytes_down) == \
               (b.time, b.round, b.bytes_up, b.bytes_down), method
        assert abs(a.accuracy - b.accuracy) <= 0.05, (method, a, b)
    print(f"ENGINE {method} OK rounds={h_a[-1].round}")
print("OK")
"""


def test_mesh_parity_subprocess():
    """The shard_map aggregation on a real 4-device host mesh: <= 1-ulp
    server parity across mesh sizes {1, 2, 4} on both receive paths, and
    timeline-exact end-to-end engine runs for teasq + fedasync.  Runs in
    a subprocess because the host-device-count flag must be set before
    jax initializes (same pattern as tests/test_fed_step.py)."""
    r = subprocess.run([sys.executable, "-c", MESH_PARITY_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SERVER-PARITY OK" in r.stdout
    assert "OK" in r.stdout.splitlines()[-1]
