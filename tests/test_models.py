"""Model correctness: decode==forward, flash==plain, prefill continuation,
SSD==naive recurrence, MoE routing properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as SSM
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
CONSISTENCY_ARCHS = ["qwen3_1_7b", "granite_34b", "smollm_135m",
                     "mamba2_370m", "jamba_v0_1_52b", "phi3_5_moe_42b",
                     "moonshot_v1_16b", "llama4_scout_17b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = T.init_model(KEY, cfg)
    S = 12
    toks = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab, (2, S)),
                       jnp.int32)
    full, _ = T.forward(params, {"tokens": toks}, cfg)
    cache = T.init_decode_state(cfg, 2, S, dtype=jnp.float32)
    for t in range(S):
        dl, cache = T.decode_step(params, toks[:, t:t + 1], jnp.int32(t),
                                  cfg, cache)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_370m", "phi3_5_moe_42b"])
def test_prefill_then_decode_continuation(arch):
    """prefill(prompt) + decode_step(next) == forward(prompt+next)."""
    cfg = get_smoke_config(arch)
    params = T.init_model(KEY, cfg)
    S = 8
    toks = jnp.asarray(np.random.RandomState(2).randint(0, cfg.vocab, (2, S + 1)),
                       jnp.int32)
    full, _ = T.forward(params, {"tokens": toks}, cfg)
    logits_p, cache = T.prefill(params, {"tokens": toks[:, :S]}, cfg)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-5, rtol=2e-5)
    if not cfg.is_ssm_only:
        cache = T.extend_cache(cache, S + 1)
    dl, _ = T.decode_step(params, toks[:, S:S + 1], jnp.int32(S), cfg, cache)
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full[:, S]),
                               atol=2e-5, rtol=2e-5)


def test_whisper_encdec_prefill_matches_forward():
    cfg = get_smoke_config("whisper_tiny")
    params = T.init_model(KEY, cfg)
    rng = np.random.RandomState(3)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (2, 8)), jnp.int32),
             "frames": jnp.asarray(rng.randn(2, cfg.enc_seq, cfg.d_model),
                                   jnp.float32)}
    full, _ = T.forward(params, batch, cfg)
    lp, cache = T.encdec_prefill(params, batch, cfg, cache_len=8)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-5)


def test_rolling_window_decode_matches_windowed_attention():
    """Rolling KV cache beyond the window == sliding-window attention."""
    cfg = get_smoke_config("qwen3_1_7b")
    params = T.init_model(KEY, cfg)
    W, S = 8, 20
    toks = jnp.asarray(np.random.RandomState(4).randint(0, cfg.vocab, (1, S)),
                       jnp.int32)
    full, _ = T.forward(params, {"tokens": toks}, cfg, window=W)
    cache = T.init_decode_state(cfg, 1, W, dtype=jnp.float32, rolling=True)
    for t in range(S):
        dl, cache = T.decode_step(params, toks[:, t:t + 1], jnp.int32(t),
                                  cfg, cache, rolling=True)
        np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full[:, t]),
                                   atol=3e-5, rtol=3e-5)


# -- attention ------------------------------------------------------------
def test_flash_matches_plain_various_chunks():
    cfg = get_smoke_config("granite_34b")  # MQA kv=1 stresses grouping
    p = A.attn_init(jax.random.PRNGKey(5), cfg)
    x = jnp.asarray(np.random.RandomState(5).randn(2, 512, cfg.d_model),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(512), (2, 512))
    o_ref = A.attn_forward(p, x, pos, cfg, flash_threshold=10 ** 9)
    o_fl = A.attn_forward(p, x, pos, cfg, flash_threshold=256)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_fl),
                               atol=1e-5, rtol=1e-5)


def test_flash_window_masks_correctly():
    cfg = get_smoke_config("qwen3_1_7b")
    p = A.attn_init(jax.random.PRNGKey(6), cfg)
    x = jnp.asarray(np.random.RandomState(6).randn(1, 256, cfg.d_model),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(256), (1, 256))
    o_w = A.attn_forward(p, x, pos, cfg, flash_threshold=64, window=32)
    o_p = A.attn_forward(p, x, pos, cfg, flash_threshold=10 ** 9, window=32)
    np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_p),
                               atol=1e-5, rtol=1e-5)
    o_full = A.attn_forward(p, x, pos, cfg, flash_threshold=10 ** 9)
    assert float(jnp.abs(o_full - o_w).max()) > 1e-3  # window actually cuts


# -- SSD vs naive per-token recurrence -------------------------------------
def test_ssd_chunked_equals_token_recurrence():
    B, S, H, P, N = 1, 32, 2, 8, 4
    rng = np.random.RandomState(7)
    xh = jnp.asarray(rng.randn(B, S, H, P).astype(np.float32))
    b = jnp.asarray(rng.randn(B, S, N).astype(np.float32)) * 0.5
    c = jnp.asarray(rng.randn(B, S, N).astype(np.float32)) * 0.5
    dt = jnp.abs(jnp.asarray(rng.randn(B, S, H).astype(np.float32))) * 0.2
    la = -jnp.abs(jnp.asarray(rng.randn(B, S, H).astype(np.float32))) * 0.1
    y_chunk, h_chunk = SSM.ssd_chunked(xh, b, c, dt, la, 8)
    # naive recurrence
    h = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(la[:, t]))                      # (B,H)
        xbar = np.asarray(xh[:, t]) * np.asarray(dt[:, t])[..., None]
        h = a[..., None, None] * h + np.einsum(
            "bhp,bn->bhpn", xbar, np.asarray(b[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(c[:, t])))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_naive, atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), h, atol=1e-4, rtol=1e-4)


# -- MoE -------------------------------------------------------------------
def test_moe_routing_topk_weights_sum_to_one():
    cfg = get_smoke_config("phi3_5_moe_42b")
    p = M.moe_init(jax.random.PRNGKey(8), cfg)
    x = jnp.asarray(np.random.RandomState(8).randn(16, cfg.d_model),
                    jnp.float32)
    w, e, probs = M._route(p["router"], x, cfg.moe_top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert int(e.max()) < cfg.n_experts
    # top-k experts are distinct per token
    assert all(len(set(row)) == cfg.moe_top_k for row in np.asarray(e))


def test_moe_load_balance_loss_minimal_when_uniform():
    probs = jnp.full((64, 4), 0.25)
    e = jnp.asarray(np.arange(128).reshape(64, 2) % 4, jnp.int32)
    lb = M._load_balance_loss(probs, e, 4)
    np.testing.assert_allclose(float(lb), 1.0, atol=1e-5)


def test_moe_dispatch_ranks_unique_per_expert():
    e = jnp.asarray(np.random.RandomState(9).randint(0, 4, (32, 2)), jnp.int32)
    rank, fe = M._dispatch_ranks(e, 4)
    rank, fe = np.asarray(rank), np.asarray(fe)
    for ex in range(4):
        r = rank[fe == ex]
        assert sorted(r) == list(range(len(r)))  # 0..count-1, unique
