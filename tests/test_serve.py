"""Serving front door: continuous batching + the FL -> serve bridge.

Three layers:

* **continuous-batching correctness** — greedy tokens out of a
  ``ContinuousBatcher`` slot must equal a solo ``generate`` of the same
  prompt, including requests admitted mid-flight into a slot another
  request just freed (the admission splice may not perturb resident
  rows, and a recycled slot's stale cache beyond the new prompt must be
  invisible behind the position mask).
* **checkpoint -> serve roundtrip** — weights pulled out of an engine or
  fleet ``state_dict`` blob via ``load_sim_params`` must equal the live
  server's weights leaf-for-leaf, and validation must reject non-LM
  tasks, bad task indices and non-checkpoint blobs loudly.
* **benchmark harness smoke** — ``benchmarks.serve_bench.run`` on a tiny
  workload without writing results, plus the merge-not-clobber
  discipline of results/serve_bench.json (tier1.sh ``-m smoke`` slice).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_sim_params, save_blob
from repro.fl.protocols import make_setup, make_sim
from repro.fl.simulator import SimConfig
from repro.fl.tasks import get_task
from repro.launch.serve import ContinuousBatcher, generate, load_task_params

P_LEN, GEN = 8, 6


@pytest.fixture(scope="module")
def lm():
    """(params, cfg, prompts, solo-greedy reference tokens) on the tiny
    FL transformer LM."""
    task = get_task("transformer_lm")
    params = task.init_params(jax.random.PRNGKey(0))
    cfg = task.model_cfg
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, P_LEN).astype(np.int32)
               for _ in range(5)]
    solo = [np.asarray(generate(params, cfg, jnp.asarray(p[None]), GEN)
                       )[0, P_LEN:].tolist() for p in prompts]
    return params, cfg, prompts, solo


# ----------------------------------------------------------------------
# continuous batching
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_batcher_matches_solo_generate(lm):
    """5 requests through 2 slots: every request's greedy tokens equal its
    solo decode — including the ones admitted only after earlier requests
    freed a slot."""
    params, cfg, prompts, solo = lm
    cb = ContinuousBatcher(params, cfg, slots=2, cache_len=P_LEN + GEN)
    outs, lat = cb.run(prompts, GEN)
    assert outs == solo
    assert len(lat) == len(prompts) and all(l > 0 for l in lat)
    # 5 requests over 2 slots need at least ceil(5/2) * (GEN-1) decode
    # steps; well under the serial 5 * (GEN-1) (the point of batching)
    assert cb.steps < 5 * (GEN - 1)


@pytest.mark.smoke
def test_mid_flight_admission_decodes_solo_tokens(lm):
    """A request admitted while another is mid-decode (slot recycled, the
    resident row several tokens in) still produces its solo token
    sequence, and the resident request is undisturbed."""
    params, cfg, prompts, solo = lm
    cb = ContinuousBatcher(params, cfg, slots=2, cache_len=P_LEN + GEN)
    r0 = cb.submit(prompts[0], GEN)
    for _ in range(3):                    # r0 is now mid-flight
        cb.step()
    r1 = cb.submit(prompts[1], GEN)
    while cb.pending():
        cb.step()
    assert cb.result(r1) == solo[1]
    assert cb.result(r0) == solo[0]


def test_slot_recycling_is_masked(lm):
    """Drive enough requests through one slot that every admission lands
    on a cache full of the previous request's state — tokens must stay
    the solo sequences (stale positions hidden by the decode mask)."""
    params, cfg, prompts, solo = lm
    cb = ContinuousBatcher(params, cfg, slots=1, cache_len=P_LEN + GEN)
    outs, _ = cb.run(prompts, GEN)
    assert outs == solo


def test_gen_one_and_validation(lm):
    params, cfg, prompts, solo = lm
    cb = ContinuousBatcher(params, cfg, slots=2, cache_len=P_LEN + GEN)
    outs, _ = cb.run([prompts[0]], 1)     # prefill-only request
    assert outs[0] == solo[0][:1]
    with pytest.raises(ValueError, match="gen"):
        cb.submit(prompts[0], 0)
    with pytest.raises(ValueError, match="cache_len"):
        cb.submit(prompts[0], GEN + 100)


def test_batcher_serves_moe_lm():
    """The batcher is family-generic over the stacked (L, B, ...) cache
    layout: the MoE LM decodes its solo tokens through shared slots."""
    task = get_task("moe_lm")
    params = task.init_params(jax.random.PRNGKey(1))
    cfg = task.model_cfg
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, P_LEN).astype(np.int32)
               for _ in range(3)]
    solo = [np.asarray(generate(params, cfg, jnp.asarray(p[None]), GEN)
                       )[0, P_LEN:].tolist() for p in prompts]
    cb = ContinuousBatcher(params, cfg, slots=2, cache_len=P_LEN + GEN)
    outs, _ = cb.run(prompts, GEN)
    assert outs == solo


# ----------------------------------------------------------------------
# checkpoint -> serve bridge
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_engine_blob(tmp_path_factory):
    """A short transformer_lm engine run checkpointed to disk; returns
    (blob path, live engine) for weight comparison."""
    data, parts, w0 = make_setup(n_devices=8, iid=True, seed=3,
                                 n_train=160, n_test=64,
                                 task="transformer_lm")
    cfg = SimConfig(method="teasq", task="transformer_lm", n_devices=8,
                    c_fraction=0.25, gamma=0.25, epochs=1, batch_size=8,
                    seed=3)
    eng = make_sim(data, parts, w0, cfg)
    eng.run(time_budget=2.0, eval_every=1)
    path = str(tmp_path_factory.mktemp("serve") / "lm_engine.msgpack")
    save_blob(path, eng.state_dict())
    return path, eng


@pytest.mark.smoke
def test_checkpoint_to_serve_roundtrip(lm_engine_blob):
    """Trained weights out of the blob equal the live server's weights
    leaf-for-leaf, and the restored model serves requests through the
    continuous-batching loop."""
    path, eng = lm_engine_blob
    assert eng.server.t >= 1          # the checkpoint holds TRAINED weights
    params, cfg = load_task_params(path, "transformer_lm")
    live = jax.tree.leaves(eng.server.w)
    got = jax.tree.leaves(params)
    assert len(live) == len(got)
    for a, b in zip(live, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    cb = ContinuousBatcher(params, cfg, slots=2, cache_len=P_LEN + GEN)
    rng = np.random.RandomState(0)
    outs, _ = cb.run([rng.randint(0, cfg.vocab, P_LEN).astype(np.int32)
                      for _ in range(3)], GEN)
    assert all(len(o) == GEN for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_fleet_blob_task_selection(tmp_path):
    """``--from-sim`` on a fleet checkpoint: ``task`` indexes the job list
    and each job's weights round-trip independently."""
    from repro.fl.fleet import FleetConfig, build_fleet
    n = 8
    spec = SimConfig(method="teasq", task="transformer_lm",
                     c_fraction=0.25, gamma=0.25, epochs=1, batch_size=8)
    fleet = build_fleet(FleetConfig(tasks=[spec, spec], n_devices=n,
                                    seed=3), n_train=160, n_test=64)
    fleet.run(time_budget=1.5)
    path = str(tmp_path / "fleet.msgpack")
    save_blob(path, fleet.state_dict())
    task = get_task("transformer_lm")
    like = task.init_params(jax.random.PRNGKey(0))
    for j, rt in enumerate(fleet.runtimes):
        params = load_sim_params(path, like, task=j)
        for a, b in zip(jax.tree.leaves(rt.server.w),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="out of range"):
        load_sim_params(path, like, task=2)


def test_bridge_validation(lm_engine_blob, tmp_path):
    path, _ = lm_engine_blob
    # non-LM task: no ModelConfig to serve
    with pytest.raises(ValueError, match="not an LM"):
        load_task_params(path, "fmnist_cnn")
    # wrong template structure fails loudly, not by position
    bad_like = {"just": np.zeros(3, np.float32)}
    with pytest.raises(ValueError, match="leaves"):
        load_sim_params(path, bad_like)
    # a non-checkpoint blob is rejected by discriminator
    other = str(tmp_path / "other.msgpack")
    save_blob(other, {"hello": 1})
    task = get_task("transformer_lm")
    like = task.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="engine or fleet"):
        load_sim_params(other, like)


# ----------------------------------------------------------------------
# benchmark harness smoke
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_serve_bench_smoke():
    from benchmarks.serve_bench import run
    rows = run(batch=2, requests=4, prompt_len=4, gen=4, out_path=None)
    assert {r["mode"] for r in rows} == {"serial", "continuous"}
    for r in rows:
        assert r["tokens_per_s"] > 0
        assert r["p99_ms"] >= r["p50_ms"] > 0
    cont = next(r for r in rows if r["mode"] == "continuous")
    assert cont["batch"] == 2 and "speedup_x" in cont
    assert cont["decode_steps"] > 0


@pytest.mark.smoke
def test_serve_bench_merges_instead_of_clobbering(tmp_path):
    from benchmarks.serve_bench import run
    out = tmp_path / "serve_bench.json"
    run(batch=2, requests=4, prompt_len=4, gen=4, out_path=str(out))
    run(batch=4, requests=4, prompt_len=4, gen=4, out_path=str(out))
    rows = json.loads(out.read_text())
    # batch=2 and batch=4 continuous rows coexist; serial rows dedupe
    assert {(r["mode"], r["batch"]) for r in rows} == \
        {("serial", 1), ("continuous", 2), ("continuous", 4)}
