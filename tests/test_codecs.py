"""Codec API property tests (hypothesis).

For every registered codec: decode∘encode is idempotent on its own output,
the packed codec's measured bytes equal the analytic
``expected_pytree_wire_bytes`` price, and wire bytes are monotone in
``p_s`` and ``p_q`` (within the sparse regime — at the dense boundary
``k == n`` the index stream is dropped, a documented discontinuity).

The always-running (hypothesis-free) codec invariants live in
tests/test_compression_invariants.py.
"""
import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.codecs import (CODECS, DenseRefCodec, PackedBitstreamCodec,
                               ThresholdGraphCodec, resolve_codec)
from repro.core.compression import expected_pytree_wire_bytes

# stay in the exactly-idempotent regime: p_q <= 16 keeps requantization
# error below half a level (see test body), p_s <= 0.5 keeps k < n
PS = st.sampled_from([0.05, 0.1, 0.25, 0.5])
PQ = st.sampled_from([2, 4, 8, 16])


def _tree(seed: int, n: int):
    rng = np.random.RandomState(seed)
    return {"a": rng.randn(n).astype(np.float32),
            "b": rng.randn(max(1, n // 3), 2).astype(np.float32)}


@settings(max_examples=25, deadline=None)
@given(p_s=PS, p_q=PQ, seed=st.integers(0, 100), n=st.integers(8, 600))
def test_dense_and_packed_idempotent_on_own_output(p_s, p_q, seed, n):
    """decode∘encode is a projection up to f32 dequantization rounding: the
    second pass reproduces the same support and the same quantization levels
    (the max kept value re-quantizes to exactly ±L), but the dequant map
    ``level * scale / L`` is not a bit-exact f32 fixed point under the
    re-measured scale, so values may drift by <= 1 ulp."""
    tree = _tree(seed, n)
    for name in ("dense", "packed"):
        codec = resolve_codec(name, p_s, p_q)
        y1, _ = codec.roundtrip(tree)
        y2, _ = codec.roundtrip(y1)
        for a, b in zip(jax.tree.leaves(y1), jax.tree.leaves(y2)):
            a, b = np.asarray(a), np.asarray(b)
            np.testing.assert_array_equal(a == 0, b == 0)   # same support
            np.testing.assert_allclose(b, a, rtol=5e-7, atol=0)


@settings(max_examples=25, deadline=None)
@given(p_s=PS, p_q=PQ, seed=st.integers(0, 100), n=st.integers(8, 600))
def test_threshold_idempotent_up_to_requant_boundaries(p_s, p_q, seed, n):
    """Re-applying the in-graph threshold channel never invents values: the
    support only shrinks (the kept fraction of the quantized output can sit
    below ``p_s``, and with coarse ``p_q`` a whole level group — values tied
    at one quantized magnitude — may drop when the binary search cannot
    split the tie), and surviving values drift <= 1 ulp."""
    tree = _tree(seed, n)
    codec = ThresholdGraphCodec(p_s, p_q)
    y1, _ = codec.roundtrip(tree)
    y2, _ = codec.roundtrip(y1)
    for a, b in zip(jax.tree.leaves(y1), jax.tree.leaves(y2)):
        a, b = np.asarray(a), np.asarray(b)
        assert np.all((a != 0) | (b == 0))                  # support shrinks
        both = (a != 0) & (b != 0)
        np.testing.assert_allclose(b[both], a[both], rtol=5e-7, atol=0)


@settings(max_examples=25, deadline=None)
@given(p_s=PS, p_q=PQ, seed=st.integers(0, 100), n=st.integers(8, 600))
def test_packed_bytes_equal_analytic_price(p_s, p_q, seed, n):
    """len() of the actual byte string == the shape-only analytic size, for
    every codec's wire_bytes answer at the same operating point."""
    tree = _tree(seed, n)
    packed = PackedBitstreamCodec(p_s, p_q)
    wire = packed.encode(tree)
    expected = expected_pytree_wire_bytes(tree, p_s, p_q)
    assert isinstance(wire.payload, bytes)
    assert len(wire.payload) == wire.nbytes == expected
    for name in CODECS:
        codec = resolve_codec(name, p_s, p_q)
        if codec.name != "identity":
            assert codec.wire_bytes(tree) == expected


@settings(max_examples=25, deadline=None)
@given(p_s=PS, p_q=PQ, seed=st.integers(0, 100), n=st.integers(8, 600),
       stochastic=st.booleans())
def test_packed_matches_dense_ref_bitwise(p_s, p_q, seed, n, stochastic):
    """Same mask, same scale, same levels — including identical RNG draw
    order under stochastic QSGD rounding."""
    tree = _tree(seed, n)
    rng_a = np.random.RandomState(seed) if stochastic else None
    rng_b = np.random.RandomState(seed) if stochastic else None
    y_p, nb_p = PackedBitstreamCodec(p_s, p_q).roundtrip(tree, rng=rng_a)
    y_d, nb_d = DenseRefCodec(p_s, p_q).roundtrip(tree, rng=rng_b)
    assert nb_p == nb_d
    for a, b in zip(jax.tree.leaves(y_p), jax.tree.leaves(y_d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100), n=st.integers(16, 600))
def test_wire_bytes_monotone_in_ps_and_pq(seed, n):
    """Within the sparse regime more aggressive compression never costs more
    bytes, for every parameterized codec."""
    tree = _tree(seed, n)
    for name in ("dense", "packed", "threshold"):
        sizes_s = [resolve_codec(name, p_s, 8).wire_bytes(tree)
                   for p_s in (0.05, 0.1, 0.25, 0.5)]
        assert sizes_s == sorted(sizes_s), (name, sizes_s)
        sizes_q = [resolve_codec(name, 0.25, p_q).wire_bytes(tree)
                   for p_q in (2, 4, 8, 16)]
        assert sizes_q == sorted(sizes_q), (name, sizes_q)
