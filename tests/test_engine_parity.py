"""Fixed-seed equivalence: the strategy-based FLEngine must reproduce the
legacy FLSimulator's LogEntry history bit-for-bit (time, round, accuracy,
byte counters) for the paper's three protocol families on a tiny synthetic
CNN workload.  This pins the refactor: the engine's default (serial) path
consumes the seeded RNG in exactly the legacy order."""
import numpy as np
import pytest

from repro.core.dynamic import CompressionSchedule
from repro.fl.protocols import make_setup, run_method


@pytest.fixture(scope="module")
def tiny_setup():
    return make_setup(n_devices=8, iid=True, seed=3, n_train=640, n_test=320)


def _histories_equal(h_a, h_b):
    assert len(h_a) == len(h_b)
    for a, b in zip(h_a, h_b):
        assert a.time == b.time
        assert a.round == b.round
        assert a.accuracy == b.accuracy
        assert a.bytes_up == b.bytes_up
        assert a.bytes_down == b.bytes_down
        assert a.max_model_bytes_up == b.max_model_bytes_up
        assert a.max_model_bytes_down == b.max_model_bytes_down


def _run_both(method, tiny_setup, **kw):
    data, parts, w0 = tiny_setup
    h_eng = run_method(method, data, parts, w0, time_budget=4.0, epochs=1,
                       seed=3, backend="engine", **kw)
    h_leg = run_method(method, data, parts, w0, time_budget=4.0, epochs=1,
                       seed=3, backend="legacy", **kw)
    return h_eng, h_leg


def test_parity_teasq_static(tiny_setup):
    h_eng, h_leg = _run_both("teasq", tiny_setup, p_s=0.25, p_q=8)
    assert h_eng[-1].round >= 1          # the run actually aggregated
    assert h_eng[-1].bytes_up > 0
    _histories_equal(h_eng, h_leg)


def test_parity_teasq_schedule(tiny_setup):
    sched = CompressionSchedule(p_s0_idx=3, p_q0_idx=2, step_size=2)
    h_eng, h_leg = _run_both("teasq", tiny_setup, schedule=sched)
    assert h_eng[-1].round >= 1
    _histories_equal(h_eng, h_leg)


def test_parity_fedasync(tiny_setup):
    h_eng, h_leg = _run_both("fedasync", tiny_setup)
    assert h_eng[-1].round >= 2          # immediate updates: many rounds
    _histories_equal(h_eng, h_leg)


def test_parity_fedavg(tiny_setup):
    h_eng, h_leg = _run_both("fedavg", tiny_setup, devices_per_round=3)
    assert h_eng[-1].round >= 1
    _histories_equal(h_eng, h_leg)


def test_parity_moon(tiny_setup):
    h_eng, h_leg = _run_both("moon", tiny_setup, devices_per_round=3)
    assert h_eng[-1].round >= 1
    _histories_equal(h_eng, h_leg)


def test_parity_tea_uncompressed(tiny_setup):
    h_eng, h_leg = _run_both("tea", tiny_setup)
    assert h_eng[-1].round >= 1
    _histories_equal(h_eng, h_leg)


def test_parity_packed_codec_drop_in(tiny_setup):
    """SimConfig.codec='packed' transmits real bit-packed bytes yet must be
    a drop-in for the dense reference codec: identical RNG draw order,
    identical decoded trees, identical byte metering — so the whole LogEntry
    history is bit-identical across codecs AND backends."""
    data, parts, w0 = tiny_setup
    kw = dict(time_budget=4.0, epochs=1, seed=3, p_s=0.25, p_q=8)
    h_dense = run_method("teasq", data, parts, w0, backend="engine", **kw)
    h_packed = run_method("teasq", data, parts, w0, backend="engine",
                          codec="packed", **kw)
    h_packed_leg = run_method("teasq", data, parts, w0, backend="legacy",
                              codec="packed", **kw)
    assert h_packed[-1].bytes_up > 0
    _histories_equal(h_dense, h_packed)
    _histories_equal(h_packed, h_packed_leg)
