"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated in its REDUCED variant (<=2
layers, d_model<=512, <=4 experts) and runs one forward + one train step on
CPU, asserting output shapes and absence of NaNs.  Full-scale configs are
only exercised through the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as T

TRANSFORMER_ARCHS = [a for a in ARCH_IDS if a != "fmnist_cnn"]


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_smoke_forward_shapes_no_nans(arch, key):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = T.init_model(key, cfg)
    batch = _batch_for(cfg)
    logits, aux = T.forward(params, batch, cfg)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_smoke_train_step(arch, key):
    """One SGD step must produce finite loss and changed, finite params."""
    cfg = get_smoke_config(arch)
    params = T.init_model(key, cfg)
    batch = _batch_for(cfg)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(
            lambda q: T.lm_loss(q, batch, cfg)[0])(p)
        return loss, jax.tree.map(lambda a, g: a - 1e-2 * g, p, grads)

    loss0, params1 = step(params)
    loss1, _ = step(params1)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params1)):
        assert np.all(np.isfinite(np.asarray(b)))
    # embedding must have moved
    assert float(jnp.abs(params1["embed"] - params["embed"]).max()) > 0


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = get_smoke_config(arch)
    params = T.init_model(key, cfg)
    cache = T.init_decode_state(cfg, batch=2, cache_len=16, dtype=jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache2 = T.decode_step(params, tok, jnp.int32(0), cfg, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # cache structure is preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_full_config_matches_assignment(arch):
    """Spot-check the full-scale configs against the assignment sheet."""
    cfg = get_config(arch)
    expected = {
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064, 16, 2),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
        "smollm_135m": (30, 576, 9, 3, 1536, 49152, 0, 0),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553, 0, 0),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865, 0, 0),
        "mamba2_370m": (48, 1024, 16, 16, 0, 50280, 0, 0),
        "llama4_scout_17b": (48, 5120, 40, 8, 8192, 202048, 16, 1),
        "moonshot_v1_16b": (48, 2048, 16, 16, 1408, 163840, 64, 6),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152, 0, 0),
        "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936, 0, 0),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab, cfg.n_experts, cfg.moe_top_k)
    assert got == expected


def test_mamba2_has_assigned_state():
    assert get_config("mamba2_370m").ssm_state == 128


def test_qwen3_has_qk_norm():
    assert get_config("qwen3_1_7b").qk_norm


def test_param_counts_in_expected_range():
    """Analytic param counts should be in the ballpark of the model names."""
    checks = {
        "smollm_135m": (0.10e9, 0.20e9),
        "qwen3_1_7b": (1.2e9, 2.4e9),
        "mamba2_370m": (0.25e9, 0.50e9),
        "granite_34b": (30e9, 40e9),
        "phi3_5_moe_42b": (38e9, 46e9),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
