"""CodecPolicy subsystem (repro.fl.policies) + per-tier Alg. 5 search.

Three layers of guarantees:

* **Inactive-policy bit-parity** — ``codec_policy="static"`` (the default)
  and ``tier_aware`` on a tierless fleet must reproduce the pinned
  pre-policy histories (tests/data/pinned_histories.json) on BOTH
  simulator backends.
* **Tier-aware byte accounting** — under heterogeneous tiers, every
  dispatch is priced by exactly the codec its device's tier was handed,
  and the per-tier meters match the analytic packed-stream price.
* **Per-tier Alg. 5** — slower bandwidth tiers end at least as compressed
  (never more wire bytes per transfer) than faster ones.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.compression import (expected_pytree_wire_bytes,
                                    expected_tensor_wire_bits)
from repro.core.dynamic import (DEFAULT_SET_Q, DEFAULT_SET_S, greedy_search,
                                greedy_search_per_tier)
from repro.fl.policies import (POLICIES, StalenessAwarePolicy, StaticPolicy,
                               TierAwarePolicy, make_policy, notch_point)
from repro.fl.protocols import (TeasqStrategy, make_setup,
                                profile_compression, run_method)
from repro.fl.simulator import (ScenarioConfig, SimConfig, TierSpec,
                                tier_assignment)

PINNED_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "pinned_histories.json")


@pytest.fixture(scope="module")
def tiny_setup():
    # same generation config as the pinned fixture (cross-checked below)
    return make_setup(n_devices=8, iid=True, seed=3, n_train=640, n_test=320)


# ----------------------------------------------------------------------
# registry + pure policy mechanics (no simulation)
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_policy_registry():
    assert set(POLICIES) == {"static", "tier_aware", "staleness_aware"}
    assert SimConfig().codec_policy == "static"
    cfg = SimConfig(n_devices=4)
    for name, cls in POLICIES.items():
        assert isinstance(make_policy(name, cfg), cls)
    with pytest.raises(ValueError, match="unknown codec policy"):
        make_policy("nope", cfg)


@pytest.mark.smoke
def test_notch_point_steps_toward_more_compression():
    assert notch_point(0.25, 8, 0) == (0.25, 8)
    assert notch_point(0.25, 8, 1) == (0.1, 4)
    # clamped at the most compressed candidates
    assert notch_point(0.25, 8, 10) == (DEFAULT_SET_S[-1], DEFAULT_SET_Q[-1])
    # off-grid points snap to the nearest candidate before stepping
    assert notch_point(0.3, 10, 1) == (0.1, 4)


@pytest.mark.smoke
def test_static_policy_is_the_pre_policy_resolution():
    from repro.core.codecs import resolve_codec
    cfg = SimConfig(n_devices=4, codec="dense")
    pol = StaticPolicy(cfg)
    # identical cached instance => identical byte accounting + RNG behavior
    assert pol.codec_for(0, 2, 0.25, 8) is resolve_codec(
        "dense", 0.25, 8, iters=cfg.cohort_channel_iters)
    assert pol.codec_for(0, None, 1.0, 32).name == "identity"


@pytest.mark.smoke
def test_tier_aware_operating_points():
    tiers = [TierSpec(0.5, 1.0, 1.0, "fast"),
             TierSpec(0.25, 1.5, 0.5, "mid"),
             TierSpec(0.25, 2.5, 0.125, "slow")]
    cfg = SimConfig(n_devices=8, scenario=ScenarioConfig(tiers=tiers))
    pol = TierAwarePolicy(cfg)
    assert list(pol.tier_of) == list(tier_assignment(8, tiers))
    # derived notches: log2(1/1)=0, log2(2)=1, log2(8)=3
    fast = pol.operating_point(pol.context(0, 0), 0.25, 8)
    mid = pol.operating_point(pol.context(0, 4), 0.25, 8)
    slow = pol.operating_point(pol.context(0, 6), 0.25, 8)
    assert fast == (0.25, 8)
    assert mid == (0.1, 4)
    assert slow == (0.01, 4)
    assert fast[0] >= mid[0] >= slow[0] and fast[1] >= mid[1] >= slow[1]
    # explicit tier_points win (e.g. the per-tier Alg. 5 output)
    cfg2 = dataclasses.replace(cfg, tier_points=[(0.5, 16), (0.25, 8),
                                                 (0.05, 4)])
    pol2 = TierAwarePolicy(cfg2)
    assert pol2.operating_point(pol2.context(0, 6), 0.25, 8) == (0.05, 4)
    # device_id=None (legacy one-arg channel_for) => tier-0 point
    assert pol2.operating_point(pol2.context(0, None), 0.25, 8) == (0.5, 16)


@pytest.mark.smoke
def test_tier_aware_without_tiers_is_inactive():
    cfg = SimConfig(n_devices=4)
    pol = TierAwarePolicy(cfg)
    assert pol.operating_point(pol.context(3, 1), 0.25, 8) == (0.25, 8)
    # and the resolved codec is the very same cached instance static picks
    assert pol.codec_for(3, 1, 0.25, 8) is \
        StaticPolicy(cfg).codec_for(3, 1, 0.25, 8)


@pytest.mark.smoke
def test_staleness_aware_ewma_and_notches():
    cfg = SimConfig(n_devices=4)
    pol = StalenessAwarePolicy(cfg)
    ctx0 = pol.context(0, 1)
    assert ctx0.staleness == 0.0
    assert pol.operating_point(ctx0, 0.25, 8) == (0.25, 8)   # fresh: base
    for _ in range(8):                      # EWMA converges toward 6
        pol.observe_arrival(1, 6)
    assert pol.staleness_est[1] > 4.0
    stale = pol.context(0, 1)
    assert pol.operating_point(stale, 0.25, 8) == \
        notch_point(0.25, 8, StalenessAwarePolicy.max_notches)
    # other devices are untouched
    assert pol.operating_point(pol.context(0, 0), 0.25, 8) == (0.25, 8)
    # uncompressed protocols (tea/fedavg) stay dense under every policy
    assert pol.codec_for(0, 1, 1.0, 32).name == "identity"


# ----------------------------------------------------------------------
# per-tier Alg. 5 search
# ----------------------------------------------------------------------
def _synthetic_eval_acc(p_s, p_q):
    """The test_protocol.py accuracy surface: acc = 0.9 - penalties."""
    pen_s = {1.0: 0.0, 0.5: 0.005, 0.25: 0.01, 0.1: 0.03,
             0.05: 0.08, 0.01: 0.2}[p_s]
    pen_q = {32: 0.0, 16: 0.002, 8: 0.008, 4: 0.06}[p_q]
    return 0.9 - pen_s - pen_q


@pytest.mark.smoke
def test_greedy_search_per_tier_monotone():
    """Slower tier => larger accuracy budget => at least as compressed =>
    never more wire bytes per transfer."""
    scales = [1.0, 0.5, 0.1]
    points, traces = greedy_search_per_tier(_synthetic_eval_acc, 0.02,
                                            scales)
    assert len(points) == len(traces) == 3
    # the full-rate tier gets exactly the paper's global search result
    si, qi, _ = greedy_search(_synthetic_eval_acc, 0.02)
    assert points[0] == (si, qi)
    n = 10_000
    prev_bits = None
    for (si, qi), b in zip(points, scales):
        assert 0 <= si < len(DEFAULT_SET_S) and 0 <= qi < len(DEFAULT_SET_Q)
        bits = expected_tensor_wire_bits(n, DEFAULT_SET_S[si],
                                         DEFAULT_SET_Q[qi])
        if prev_bits is not None:
            assert bits <= prev_bits, \
                f"slower tier (bw {b}) costs more wire than a faster one"
        prev_bits = bits
    # the searched indices themselves are monotone too
    assert points[0][0] <= points[1][0] <= points[2][0]
    assert points[0][1] <= points[1][1] <= points[2][1]
    # strictly more compression is actually reached on this surface
    assert points[2] != points[0]


def test_profile_compression_tiered_returns_points(tiny_setup):
    data, _, w0 = tiny_setup
    tiers = [TierSpec(0.5, 1.0, 1.0), TierSpec(0.5, 1.0, 0.25)]
    points, traces = profile_compression(w0, data, theta=0.05, tiers=tiers)
    assert len(points) == len(traces) == 2
    for p_s, p_q in points:
        assert p_s in DEFAULT_SET_S and p_q in DEFAULT_SET_Q
    # directly usable as SimConfig.tier_points
    cfg = SimConfig(n_devices=8, tier_points=points,
                    scenario=ScenarioConfig(tiers=tiers))
    pol = TierAwarePolicy(cfg)
    assert pol.operating_point(pol.context(0, 7), 0.25, 8) == \
        (float(points[1][0]), int(points[1][1]))


# ----------------------------------------------------------------------
# inactive-policy bit-parity against the pinned pre-policy histories
# ----------------------------------------------------------------------
@pytest.mark.parametrize("method,backend,policy", [
    ("teasq", "engine", "static"),
    ("teasq", "legacy", "static"),
    ("teasq", "engine", "tier_aware"),
    ("teasq", "legacy", "tier_aware"),
    ("fedasync", "engine", "tier_aware"),
    ("fedavg", "engine", "tier_aware"),
])
def test_inactive_policy_pinned_parity(method, backend, policy, tiny_setup):
    """An explicit static policy — and tier_aware on a fleet with no tiers —
    must leave every protocol's LogEntry history bit-identical to the
    pinned pre-policy fixture, on both backends."""
    with open(PINNED_PATH) as f:
        pinned = json.load(f)
    assert pinned["setup"] == dict(n_devices=8, iid=True, seed=3,
                                   n_train=640, n_test=320)  # == tiny_setup
    data, parts, w0 = tiny_setup
    hist = run_method(method, data, parts, w0, backend=backend,
                      codec_policy=policy, **pinned["run_kw"],
                      **pinned["runs"][method])
    got = [dataclasses.asdict(h) for h in hist]
    assert got == pinned["histories"][method], \
        f"{method}/{backend}/{policy} drifted from the pre-policy fixture"


# ----------------------------------------------------------------------
# tier-aware end-to-end byte accounting
# ----------------------------------------------------------------------
def test_tier_aware_per_tier_byte_accounting(tiny_setup):
    """Heterogeneous run: every dispatch must be priced by exactly the codec
    its device's tier was handed, the per-tier meters must match the
    analytic packed-stream price, and the slow tier must pay strictly fewer
    bytes per transfer than the fast tier."""
    from repro.fl.engine import FLEngine

    data, parts, w0 = tiny_setup
    tiers = [TierSpec(0.5, 1.0, 1.0, "fast"),
             TierSpec(0.5, 1.0, 0.125, "slow")]
    tier_points = [(0.25, 8), (0.01, 4)]
    cfg = SimConfig(method="teasq", n_devices=len(parts), p_s=0.25, p_q=8,
                    epochs=1, batch_size=8, seed=3, c_fraction=0.5,
                    gamma=0.25, codec_policy="tier_aware",
                    tier_points=tier_points,
                    scenario=ScenarioConfig(tiers=tiers))

    class Recording(TeasqStrategy):
        def __init__(self, cfg):
            super().__init__(cfg)
            self.seen = []

        def channel_for(self, t, device_id=None):
            codec = super().channel_for(t, device_id)
            self.seen.append((device_id, codec))
            return codec

    strat = Recording(cfg)
    eng = FLEngine(data, parts, w0, cfg, strategy=strat)
    hist = eng.run(time_budget=3.0, eval_every=10 ** 9)

    tier_of = tier_assignment(len(parts), tiers)
    prices = [expected_pytree_wire_bytes(w0, p_s, p_q)
              for p_s, p_q in tier_points]
    assert prices[1] < prices[0]        # slow tier: strictly cheaper/upload

    assert strat.seen and {tier_of[d] for d, _ in strat.seen} == {0, 1}
    expected = {0: 0, 1: 0}
    for d, codec in strat.seen:
        tier = int(tier_of[d])
        # the codec handed out is the tier's searched operating point...
        assert (codec.p_s, codec.p_q) == tier_points[tier]
        # ...and its price is the analytic packed-stream price
        assert codec.wire_bytes(w0) == prices[tier]
        expected[tier] += prices[tier]

    # serial path: down + up per dispatch, both through the tier's codec
    assert eng.channel.tier_down == expected
    assert eng.channel.tier_up == expected
    assert hist[-1].bytes_down == sum(expected.values())
    assert hist[-1].bytes_up == sum(expected.values())
    assert hist[-1].max_model_bytes_up == prices[0]


def test_staleness_aware_never_exceeds_static_bytes(tiny_setup):
    """staleness_aware only ever adds compression notches, so a run's total
    wire bytes are bounded by the static policy's run (equal only if no
    device ever crossed the staleness threshold)."""
    data, parts, w0 = tiny_setup
    kw = dict(time_budget=4.0, epochs=1, seed=3, p_s=0.25, p_q=8)
    h_static = run_method("teasq", data, parts, w0, backend="engine", **kw)
    h_stale = run_method("teasq", data, parts, w0, backend="engine",
                         codec_policy="staleness_aware", **kw)
    assert h_stale[-1].bytes_up > 0
    assert h_stale[-1].bytes_up <= h_static[-1].bytes_up
    assert h_stale[-1].max_model_bytes_up <= h_static[-1].max_model_bytes_up
