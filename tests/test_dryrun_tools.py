"""The dry-run measurement tooling: HLO parsers (trip-count-aware flops /
bytes / collectives), roofline analysis, input specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.dryrun import (collective_bytes, hlo_bytes, hlo_flops,
                                 _parse_computations)


def test_flops_exact_on_matmul():
    c = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((128, 256)), jnp.zeros((256, 64))).compile()
    assert hlo_flops(c.as_text()) == 2 * 128 * 256 * 64


def test_flops_trip_count_aware():
    def f(c, w):   # w traced so XLA cannot constant-fold the dot away
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), c, None, length=7)
        return out

    c = jax.jit(f).lower(jnp.zeros((64, 64)), jnp.zeros((64, 64))).compile()
    assert hlo_flops(c.as_text()) == 7 * 2 * 64 ** 3
    # XLA's own cost_analysis undercounts ~7x (documents why we need ours);
    # on some jax versions it returns a one-element list per device
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < 1.01 * 2 * 64 ** 3


def test_flops_grad_counts_both_dots():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)
    c = jax.jit(jax.grad(loss)).lower(
        jnp.zeros((256, 64)), jnp.zeros((32, 256))).compile()
    got = hlo_flops(c.as_text())
    expect = 2 * (2 * 32 * 256 * 64)
    assert abs(got - expect) / expect < 0.01


def test_bytes_scale_with_trips():
    w = jnp.zeros((128, 128))

    def f(c, n):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), c, None, length=n)
        return out

    b2 = hlo_bytes(jax.jit(lambda c: f(c, 2)).lower(
        jnp.zeros((128, 128))).compile().as_text())
    b8 = hlo_bytes(jax.jit(lambda c: f(c, 8)).lower(
        jnp.zeros((128, 128))).compile().as_text())
    assert 2.5 < b8 / b2 < 4.5   # ~4x more loop traffic (fixed overhead)


def test_parse_computations_handles_tuple_params():
    txt = """HloModule m

%body (arg: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  ROOT %t = (s32[], f32[4,4]) tuple(%p)
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4] parameter(0)
  ROOT %out = f32[4,4] copy(%x)
}
"""
    comps, entry = _parse_computations(txt)
    assert "body" in comps and entry == "main"


def test_collective_bytes_ring_estimates():
    txt = """HloModule m

ENTRY %main (x: f32[16,1024]) -> f32[16,1024] {
  %x = f32[16,1024] parameter(0)
  %ag = f32[16,1024] all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %ar = f32[16,1024] all-reduce(%ag), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    out = collective_bytes(txt, 256)
    nbytes = 16 * 1024 * 4
    frac = 15 / 16
    np.testing.assert_allclose(out["all-gather"], nbytes * frac)
    np.testing.assert_allclose(out["all-reduce"], 2 * nbytes * frac)
    np.testing.assert_allclose(out["total"], 3 * nbytes * frac)


def test_roofline_analyze():
    from benchmarks import roofline as RL
    rec = {
        "arch": "qwen3_1_7b", "shape": "train_4k", "mesh": "16x16",
        "step": "fed", "params": 2e9, "active_params": 2e9,
        "fed": {"local_steps": 1},
        "cost": {"flops_trip_aware": 1e13, "bytes_trip_aware": 1e12,
                 "flops": 1e11, "bytes accessed": 1e10},
        "collectives": {"total": 5e10},
        "memory": {"temp_size_in_bytes": 10 ** 10},
    }
    row = RL.analyze(rec, 256)
    assert row["dominant"] == "memory"
    np.testing.assert_allclose(row["compute_s"], 1e13 / 197e12)
    np.testing.assert_allclose(row["collective_s"], 1.0)
    # uses the trip-aware flops, not the raw ones
    expected_ratio = (6 * 2e9 * 4096 * 256) / (1e13 * 256)
    np.testing.assert_allclose(row["useful_ratio"], expected_ratio)


def test_input_specs_cover_all_modalities():
    from repro.launch import specs as S
    from repro.configs.base import get_config
    whisper = get_config("whisper_tiny")
    b = S.batch_specs(whisper, "train_4k")
    assert "frames" in b and b["frames"].shape == (256, 1500, 384)
    vlm = get_config("internvl2_2b")
    b = S.batch_specs(vlm, "train_4k")
    assert "patches" in b and b["patches"].shape == (256, 256, 2048)
    # decode specs: SSM has state not kv
    tok, cache, pos, rolling = S.decode_specs(get_config("mamba2_370m"),
                                              "long_500k")
    flat = jax.tree_util.tree_leaves(cache)
    assert not rolling  # ssm decodes natively, no window
    # dense long_500k rolls an 8k window
    tok, cache, pos, rolling = S.decode_specs(get_config("qwen3_1_7b"),
                                              "long_500k")
    assert rolling
    k = cache["k"]
    assert k.shape[2] == S.WINDOW


def test_fed_group_dp_math_identical_no_mesh():
    """group_parallelism only changes sharding; without a mesh the numbers
    are identical."""
    from repro.configs.base import get_smoke_config
    from repro.core.fed_step import FedConfig, make_fed_train_step
    from repro.models import transformer as T
    cfg = get_smoke_config("smollm_135m")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, (4, 32)), jnp.int32)}
    stale = jnp.zeros(2, jnp.int32)
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg)[0]
    outs = []
    for gp in ("tp", "dp"):
        fed = FedConfig(n_groups=2, local_steps=1, lr=1e-2,
                        schedule="gather_q", group_parallelism=gp)
        p1, m = jax.jit(make_fed_train_step(loss_fn, fed))(params, batch, stale)
        outs.append((p1, float(m["local_loss"])))
    assert outs[0][1] == outs[1][1]
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
