"""Wave handler suite (``SimConfig.handler_mode="wave"``).

Wave mode replaces the batched scheduler's scalar per-event handlers with
vectorized per-kind waves (``BatchedEngine._run_wave``) under a documented
*relaxed*-parity contract — RNG draws batched per wave in device-index
order, spawned events observing post-wave server state, tensordot
reassociation in the stacked Eqs. 6-10 aggregation, and the version-deduped
zero-step cohort path.  This suite pins what the contract still guarantees:

* **smoke** — wave end-to-end per protocol family + mode validation +
  ``_FifoWaiting.pop_many``/compaction units (tier1.sh ``-m smoke`` slice).
* **exact relaxed parity** — fleets with ``ComputeConfig(phi=inf)``:
  ``rng.exponential(scale=0.0)`` returns exactly ``0.0`` while consuming
  the same stream positions, so per-device latencies — and therefore every
  device's event timeline — are bit-identical *regardless of draw order*.
  On such fleets with a non-binding admission gate (``c_fraction=1.0``)
  the wave run must match the heap reference exactly on every
  timeline-level quantity: the pending-event multiset, per-device
  completion counts, dispatch/completion stats, global and per-tier
  ChannelMeter totals, round count and resume cursor.  Event *processing*
  may regroup (an arrival wave handles its whole span before re-grant
  arrivals landing inside it), so per-round instants, cache grouping and
  model values are the documented relaxed part; the fused aggregation's
  values are pinned separately by the ``receive_many`` unit test.  A
  hypothesis property variant explores the same fleet space.
* **gate-binding conservation** — with ``c_fraction < 1`` wave admission
  legitimately diverges (the gate observes post-wave active counts), so
  the checks become single-run invariants: liveness, exact wire-byte
  accounting, and an equal ``max_rounds`` stopping point on both paths.
* **serial re-pin** — ``handler_mode="serial"`` (explicitly passed) stays
  on the pinned-fixture manifold on both schedulers and the degenerate
  fleet; adding the knob must not move the default path by one bit.
* **scale** (opt-in ``-m scale``) — the 10^6-device wave stress run
  mirroring ``test_batched_5000_device_stress``: dropout + transient
  failure + 3 tiers at one sample/device, which also drives the wave-only
  ``_zero_step_round`` version-deduped cohort path.
"""
import dataclasses
import functools
import json

from conftest import (PINNED_PATH, TINY_SETUP, assert_engine_state_equal,
                      assert_histories_equal, run_tiny)
import jax
import numpy as np
import pytest

from repro.checkpoint.io import load_blob, save_blob
from repro.core.compression import expected_pytree_wire_bytes
from repro.core.latency import ComputeConfig, WirelessConfig
from repro.data.synthetic import partition_iid
from repro.fl.engine import BatchedEngine, KIND_NAMES, _FifoWaiting
from repro.fl.fleet import FleetConfig, MultiTaskEngine, build_fleet
from repro.fl.protocols import make_setup, make_sim
from repro.fl.simulator import ScenarioConfig, SimConfig, TierSpec
from repro.fl.tasks import get_task

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # the grid tests below still pin the parity
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------------
# smoke: wave end-to-end + plumbing units
# ----------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("method", ["teasq", "fedasync", "fedavg"])
def test_smoke_wave_end_to_end(method, tiny_setup):
    """A small wave-mode end-to-end run per protocol family — the fused
    TEA arrival path, a non-fused async baseline, and a synchronous
    protocol (where the mode is accepted and inert)."""
    kw = (dict(devices_per_round=3) if method == "fedavg"
          else dict(p_s=0.25, p_q=8))
    hist = run_tiny(method, tiny_setup, time_budget=2.0,
                    scheduler="batched", handler_mode="wave", **kw)
    assert hist[-1].round >= 1
    assert np.isfinite(hist[-1].accuracy)
    assert hist[-1].bytes_up > 0


@pytest.mark.smoke
def test_wave_mode_validation(tiny_setup):
    data, parts, w0 = tiny_setup
    cfg = SimConfig(n_devices=len(parts), scheduler="heap",
                    handler_mode="wave")
    with pytest.raises(ValueError, match="batched"):
        make_sim(data, parts, w0, cfg)
    cfg = SimConfig(n_devices=len(parts), scheduler="batched",
                    handler_mode="vector")
    with pytest.raises(ValueError, match="unknown handler_mode"):
        make_sim(data, parts, w0, cfg)
    sim = make_sim(data, parts, w0,
                   SimConfig(n_devices=len(parts), scheduler="batched",
                             handler_mode="wave"))
    assert isinstance(sim, BatchedEngine) and sim.supports_wave


@pytest.mark.smoke
def test_fifo_pop_many_matches_scalar_pops():
    """pop_many(g) == g scalar pop(0) calls, interleaved with appends and
    whole-wave extends, across compaction boundaries."""
    fifo, ref = _FifoWaiting(), []
    rng = np.random.RandomState(7)
    for step in range(3000):
        r = rng.random_sample()
        if r < 0.4:
            ks = list(range(step * 10, step * 10 + rng.randint(1, 6)))
            fifo.extend(ks)
            ref.extend(ks)
        elif r < 0.7:
            fifo.append(step)
            ref.append(step)
        else:
            g = rng.randint(0, 8)
            got = fifo.pop_many(g)
            want, ref = ref[:g], ref[g:]
            assert got == want
        assert len(fifo) == len(ref)
    assert fifo.pop_many(len(fifo) + 100) == ref   # drain past the end
    assert len(fifo) == 0 and fifo.pop_many(5) == []


@pytest.mark.smoke
def test_fifo_pop_many_compaction_threshold_at_depth():
    """The 10^5-deep drain the wave path performs after the initial
    request burst: one slice pop of the granted block must physically
    compact the buffer once the head cursor passes the threshold
    (head > 1024 and head*2 >= len), and never before."""
    fifo = _FifoWaiting()
    depth = 10 ** 5
    fifo.extend(range(depth))
    # below the ratio: head = 1/4 of the buffer -> no compaction yet
    assert fifo.pop_many(depth // 4) == list(range(depth // 4))
    assert fifo._head == depth // 4 and len(fifo._items) == depth
    # crossing the ratio: head = 60% of the buffer -> one compaction
    assert fifo.pop_many(depth // 4 + depth // 10) == \
        list(range(depth // 4, depth // 2 + depth // 10))
    assert fifo._head == 0                       # compacted in one slice
    assert len(fifo._items) == depth - (depth // 2 + depth // 10)
    assert len(fifo) == len(fifo._items)
    # small queues never compact (head <= 1024 guard)
    small = _FifoWaiting()
    small.extend(range(100))
    small.pop_many(90)
    assert small._head == 90 and len(small._items) == 100
    assert small.pop_many(100) == list(range(90, 100))


@pytest.mark.smoke
def test_receive_many_matches_scalar_receive():
    """The wave Receiver (``receive_many`` + ``aggregate_cache_stacked``)
    must replay K scalar ``receive`` calls: identical done flags, round
    counter and cache depth, and allclose aggregated weights (tensordot
    reassociates the Eqs. 6-10 reduction — the permitted divergence)."""
    from repro.core.server import ServerConfig, TeasqServer
    rng = np.random.RandomState(0)
    w0 = {"w1": rng.randn(6, 4).astype(np.float32),
          "b": rng.randn(4).astype(np.float32)}
    cfg = ServerConfig(n_devices=10, gamma=0.3)      # K = 3
    srv_a = TeasqServer(dict(w0), cfg)
    srv_b = TeasqServer(dict(w0), cfg)
    entries = [({"w1": rng.randn(6, 4).astype(np.float32),
                 "b": rng.randn(4).astype(np.float32)},
                max(0, i % 4 - 1), 10 + 3 * i)
               for i in range(8)]
    srv_a.active = srv_b.active = 8                  # receive decrements
    done_a = [srv_a.receive(*e) for e in entries]
    done_b = srv_b.receive_many(entries[:5]) + srv_b.receive_many(
        entries[5:])
    assert done_a == done_b
    assert (srv_a.t, len(srv_a.cache)) == (srv_b.t, len(srv_b.cache))
    assert srv_a.active == srv_b.active
    for leaf in w0:
        np.testing.assert_allclose(np.asarray(srv_a.w[leaf]),
                                   np.asarray(srv_b.w[leaf]),
                                   rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------------
# exact relaxed parity: zero-noise fleets, non-binding gate
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=16)
def _wave_setup(n_devices, seed):
    return make_setup(n_devices=n_devices, iid=True, seed=seed,
                      n_train=40 * n_devices, n_test=160)


def _pending_events(eng):
    """Multiset of pending (time, kind, device) events, engine-agnostic."""
    if eng._events is not None:                     # heap scheduler
        return sorted((t, kind, k) for t, _, kind, k, _, _ in eng._events)
    table = eng.devices.events
    live = np.flatnonzero(np.isfinite(table.time))
    return sorted((float(table.time[k]), KIND_NAMES[table.kind[k]], int(k))
                  for k in live.tolist())


def _check_wave_exact(n_devices, method, codec, cohort, seed, tiered,
                      bw_scale):
    """Run one zero-compute-noise fleet under the heap reference and the
    wave path and assert the per-device event timelines are identical.

    With ``c_fraction=1.0`` the gate never binds, so each device's
    trajectory is independent of every other device: grant at its own
    event time, next arrival at grant + deterministic latency.  Wave mode
    may *process* those events regrouped (an arrival wave spanning
    [t0, t1] handles all its members before a re-grant arrival that lands
    inside the span — the contract's post-wave-state relaxation), which
    legitimately moves round-completion instants and the per-round cache
    grouping.  What cannot move is the timeline itself: the pending-event
    multiset, per-device completion counts, dispatch/completion totals,
    global and per-tier wire bytes, the final round count, and the resume
    cursor must all be exact."""
    tiers = None
    if tiered:
        tiers = [TierSpec(0.5, compute_scale=1.0, bandwidth_scale=1.0,
                          name="fast"),
                 TierSpec(0.5, compute_scale=2.0,
                          bandwidth_scale=float(bw_scale), name="slow")]
    scenario = ScenarioConfig(tiers=tiers) if tiers else None
    data, parts, w0 = _wave_setup(n_devices, seed)
    engines = []
    for scheduler, mode in (("heap", "serial"), ("batched", "wave")):
        cfg = SimConfig(method=method, task="fmnist_cnn",
                        n_devices=n_devices, c_fraction=1.0, gamma=0.25,
                        epochs=1, batch_size=8, p_s=0.25, p_q=8, seed=seed,
                        codec=codec, scenario=scenario, cohort_size=cohort,
                        cohort_channel_iters=6, scheduler=scheduler,
                        handler_mode=mode,
                        compute=ComputeConfig(phi=float("inf")))
        eng = make_sim(data, parts, w0, cfg)
        hist = eng.run(time_budget=2.0, eval_every=1)
        engines.append((eng, hist))
    (e_ref, h_ref), (e_wav, h_wav) = engines
    assert h_ref[-1].bytes_down > 0               # fleets actually dispatch

    # history: same round sequence (one eval row per completed round),
    # model values plausible.  Row *times* and intermediate byte columns
    # are the relaxed part — round grouping may shift within a wave's
    # span — but the tail row observes the drained end state, where the
    # clock and the byte totals must agree again.
    assert len(h_ref) == len(h_wav)
    assert [h.round for h in h_ref] == [h.round for h in h_wav]
    assert all(np.isfinite(h.accuracy) and 0.0 <= h.accuracy <= 1.0
               for h in h_wav)
    a, b = h_ref[-1], h_wav[-1]
    assert a.time == b.time
    assert (a.bytes_up, a.bytes_down,
            a.max_model_bytes_up, a.max_model_bytes_down) == \
           (b.bytes_up, b.bytes_down,
            b.max_model_bytes_up, b.max_model_bytes_down)

    # channel meters + stats + per-device task counts: exact
    ca, cb = e_ref.channel, e_wav.channel
    assert (ca.bytes_up, ca.bytes_down, ca.max_up, ca.max_down) == \
           (cb.bytes_up, cb.bytes_down, cb.max_up, cb.max_down)
    assert ca.tier_up == cb.tier_up and ca.tier_down == cb.tier_down
    sa, sb = e_ref.stats, e_wav.stats
    assert (sa.dispatches, sa.completions, sa.dropouts,
            sa.transient_failures, sa.redispatched) == \
           (sb.dispatches, sb.completions, sb.dropouts,
            sb.transient_failures, sb.redispatched)
    np.testing.assert_array_equal(sa.completed_per_device,
                                  sb.completed_per_device)

    # final server state: same round counter, occupancy and cache depth
    # (cache *membership* may regroup with the rounds)
    assert e_ref.server.t == e_wav.server.t
    assert e_ref.server.active == e_wav.server.active
    assert len(e_ref.server.cache) == len(e_wav.server.cache)

    # pending-event multiset: the exact same events remain scheduled, so
    # a resumed run starts from the same frontier
    assert _pending_events(e_ref) == _pending_events(e_wav)


# each row: (n_devices, method, codec, cohort_size, seed, tiered, bw_scale)
WAVE_GRID = [
    (6, "teasq", "dense", 0, 0, False, 1.0),
    (8, "teasq", "packed", 4, 1, True, 0.25),
    (12, "teasq", "dense", 3, 2, True, 0.125),
    (7, "teasq", "packed", 0, 3, True, 0.5),
    (9, "fedasync", "dense", 0, 4, False, 1.0),
    (10, "fedasync", "packed", 3, 5, True, 0.5),
]


@pytest.mark.parametrize("fleet", WAVE_GRID,
                         ids=lambda f: f"{f[1]}_n{f[0]}_s{f[4]}")
def test_wave_exact_parity_grid(fleet):
    """The always-running slice of the wave property suite: seeded
    zero-noise fleets across protocol/codec/trainer/tier axes."""
    _check_wave_exact(*fleet)


if HAVE_HYPOTHESIS:
    wave_fleet_strategy = st.fixed_dictionaries(dict(
        n_devices=st.integers(min_value=4, max_value=12),
        method=st.sampled_from(("teasq", "fedasync")),
        codec=st.sampled_from(("dense", "packed")),
        cohort=st.sampled_from([0, 0, 3]),
        seed=st.integers(min_value=0, max_value=7),
        tiered=st.booleans(),
        bw_scale=st.sampled_from([1.0, 0.5, 0.125]),
    ))

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(fleet=wave_fleet_strategy)
    def test_wave_exact_parity_hypothesis(fleet):
        """Property form of the grid: hypothesis explores the zero-noise
        fleet space (and shrinks a violation to a minimal fleet)."""
        _check_wave_exact(**fleet)


# ----------------------------------------------------------------------
# gate-binding conservation: where wave admission legitimately diverges
# ----------------------------------------------------------------------
def test_wave_gate_binding_conservation(tiny_setup):
    """Under a binding admission gate plus an active failure scenario the
    wave grant order is allowed to differ (the relaxed-parity contract),
    but conservation must hold on the wave run itself: slot liveness and
    exact per-dispatch wire-byte accounting."""
    n = 64
    data, parts, w0 = _wave_setup(n, 0)
    scen = ScenarioConfig(dropout_prob=0.05, failure_prob=0.1,
                          retry_backoff=0.1)
    cfg = SimConfig(method="teasq", task="fmnist_cnn", n_devices=n,
                    c_fraction=0.125, gamma=8.0 / n, epochs=1,
                    batch_size=8, p_s=0.25, p_q=8, seed=0, codec="packed",
                    scenario=scen, cohort_size=4, cohort_channel_iters=6,
                    scheduler="batched", handler_mode="wave")
    eng = make_sim(data, parts, w0, cfg)
    hist = eng.run(time_budget=8.0, eval_every=10 ** 9)
    s = eng.stats
    assert hist[-1].round >= 1 and s.completions > 0
    in_flight = s.dispatches - s.completions - s.dropouts \
        - s.transient_failures
    assert 0 <= in_flight <= eng.server.cfg.max_parallel
    assert in_flight == eng.server.active
    table = eng.devices.events
    live = np.isfinite(table.time)
    # the wave loop never clears an unprocessed event, so every in-flight
    # task keeps its arrival/failure event resident — exactly
    assert int((table.kind[live] > 0).sum()) == in_flight
    per_task = expected_pytree_wire_bytes(w0, cfg.p_s, cfg.p_q)
    ch = eng.channel
    assert ch.bytes_down == s.dispatches * per_task
    assert ch.bytes_up % per_task == 0
    pending_fail = int((table.kind[live] == 2).sum())
    assert s.dispatches - s.dropouts - s.transient_failures \
        - ch.bytes_up // per_task == pending_fail


def test_wave_max_rounds_stop_matches_serial(tiny_setup):
    """Both processing modes must stop at the same aggregation round under
    ``max_rounds`` even where per-event order diverges."""
    data, parts, w0 = tiny_setup
    rounds = []
    for mode in ("serial", "wave"):
        cfg = SimConfig(method="teasq", n_devices=len(parts), epochs=1,
                        p_s=0.25, p_q=8, seed=3, scheduler="batched",
                        handler_mode=mode)
        eng = make_sim(data, parts, w0, cfg)
        hist = eng.run(time_budget=50.0, max_rounds=6, eval_every=1)
        rounds.append((hist[-1].round, eng.server.t))
    assert rounds[0] == rounds[1] == (6, 6)


# ----------------------------------------------------------------------
# serial re-pin: the default path must not move by one bit
# ----------------------------------------------------------------------
@pytest.mark.parametrize("scheduler", ["heap", "batched"])
def test_serial_mode_repins_fixtures(scheduler, tiny_setup):
    """``handler_mode="serial"`` passed explicitly replays the pinned
    batched-path fixture bit-for-bit on both schedulers — the knob's
    default wiring cannot perturb the serial machine."""
    with open(PINNED_PATH) as f:
        pinned = json.load(f)
    assert pinned["setup"] == TINY_SETUP
    kw = pinned["runs_batched"]["teasq"]
    hist = run_tiny("teasq", tiny_setup, task="fmnist_cnn",
                    **pinned["run_kw"],
                    **{**kw, "scheduler": scheduler,
                       "handler_mode": "serial"})
    got = [dataclasses.asdict(h) for h in hist]
    assert got == pinned["histories_batched"]["teasq"]


def test_fleet_serial_mode_matches_engine(tiny_setup):
    """A degenerate single-task fleet with the (default) serial mode stays
    bit-identical to the standalone batched engine — FleetConfig's
    handler_mode plumbing defaults to the pinned path."""
    data, parts, w0 = tiny_setup
    spec = SimConfig(method="teasq", n_devices=len(parts), c_fraction=0.1,
                     mu=0.01, alpha=0.6, p_s=0.25, p_q=8, epochs=1, seed=3)
    fleet = MultiTaskEngine([data], [parts], [w0], FleetConfig(
        tasks=[spec], n_devices=len(parts), seed=3, scheduler="batched",
        handler_mode="serial"))
    h_fleet = fleet.run(time_budget=4.0)[0]
    h_eng = run_tiny("teasq", tiny_setup, scheduler="batched")
    assert_histories_equal(h_fleet, h_eng)


def test_fleet_wave_smoke():
    """A two-job fleet in wave mode: task-id-aware request/arrival waves
    keep both jobs making progress and conserve the shared device pool."""
    n = 32
    cfg = FleetConfig(
        tasks=[SimConfig(method="teasq", task="fmnist_cnn", c_fraction=0.4,
                         gamma=4.0 / n, epochs=1, p_s=0.25, p_q=8,
                         cohort_size=4, cohort_channel_iters=6),
               SimConfig(method="teasq", task="fmnist_mlp", c_fraction=0.4,
                         gamma=4.0 / n, epochs=1, p_s=0.25, p_q=8,
                         cohort_size=4, cohort_channel_iters=6)],
        n_devices=n, seed=0, scheduler="batched", handler_mode="wave")
    fleet = build_fleet(cfg, n_train=n * 4, n_test=80)
    hists = fleet.run(time_budget=4.0, eval_every=10 ** 9)
    assert all(h[-1].round >= 1 for h in hists)
    busy = sum(rt.server.active for rt in fleet.runtimes)
    assert 0 <= busy <= n


# ----------------------------------------------------------------------
# scale: the 10^6-device wave stress run (opt-in)
# ----------------------------------------------------------------------
@pytest.mark.scale
def test_wave_million_device_stress():
    """Million-device wave stress mirroring
    ``test_batched_5000_device_stress``: dropout + transient failure +
    3 heterogeneity tiers at one sample per device (so every cohort flush
    drives the wave-only ``_zero_step_round`` version-deduped path), with
    the same liveness and exact wire-byte accounting bars."""
    n = 10 ** 6
    task = get_task("fmnist_mlp")
    data = task.make_data(n, 1000, 0)
    parts = partition_iid(n, n, 0)
    import jax
    w0 = task.init_params(jax.random.PRNGKey(0))
    tiers = [TierSpec(0.3, compute_scale=1.0, bandwidth_scale=1.0,
                      name="fast"),
             TierSpec(0.4, compute_scale=1.5, bandwidth_scale=0.5,
                      name="mid"),
             TierSpec(0.3, compute_scale=2.5, bandwidth_scale=0.125,
                      name="slow")]
    scen = ScenarioConfig(dropout_prob=0.02, failure_prob=0.05,
                          retry_backoff=0.2, tiers=tiers)
    cfg = SimConfig(method="teasq", task="fmnist_mlp", n_devices=n,
                    c_fraction=0.1, gamma=10.0 / n, epochs=1, batch_size=8,
                    p_s=0.25, p_q=8, seed=0, scheduler="batched",
                    handler_mode="wave", cohort_size=256,
                    cohort_channel_iters=6,
                    wireless=WirelessConfig(bandwidth_hz=2e5),
                    scenario=scen)
    eng = make_sim(data, parts, w0, cfg)
    hist = eng.run(time_budget=0.4, eval_every=10 ** 9)
    s = eng.stats
    assert isinstance(eng, BatchedEngine)
    assert hist[-1].round >= 1
    assert s.completions > 0
    assert s.dropouts > 0 and s.transient_failures > 0
    assert int(eng.devices.alive.sum()) == n - s.dropouts

    in_flight = s.dispatches - s.completions - s.dropouts \
        - s.transient_failures
    assert 0 <= in_flight <= eng.server.cfg.max_parallel
    assert in_flight == eng.server.active
    table = eng.devices.events
    live = np.isfinite(table.time)
    assert int((table.kind[live] > 0).sum()) == in_flight

    per_task = expected_pytree_wire_bytes(w0, cfg.p_s, cfg.p_q)
    ch = eng.channel
    assert ch.bytes_down == s.dispatches * per_task
    assert ch.bytes_up % per_task == 0
    pending_fail = int((table.kind[live] == 2).sum())
    assert s.dispatches - s.dropouts - s.transient_failures \
        - ch.bytes_up // per_task == pending_fail
    assert set(ch.tier_down) == {0, 1, 2}
    assert sum(ch.tier_down.values()) == ch.bytes_down
    assert sum(ch.tier_up.values()) == ch.bytes_up
    for tier_bytes in ch.tier_down.values():
        assert tier_bytes % per_task == 0


# ----------------------------------------------------------------------
# wave resume parity: save-at-t equals the uninterrupted wave run
# ----------------------------------------------------------------------
# Two layers, on the zero-noise fleets of the exact-parity section
# (``ComputeConfig(phi=inf)``: every latency draw is exactly 0.0 regardless
# of assignment order):
#
# 1. **The checkpoint pin proper — bit-exact.**  Restoring the blob saved
#    at the cut must be indistinguishable from never having serialized:
#    the restored engine replays the same engine *continued past the save*
#    bit-for-bit — full histories, channel meters, stats, the
#    pending-event multiset, and the server weights to the last bit.
#
# 2. **The cut itself, vs the uninterrupted run — relaxed.**  A budget cut
#    splits waves, and a wave handles its whole same-kind span before
#    events spawned inside it (the post-wave-state regrouping documented
#    on ``BatchedEngine``), so processing order near the cut regroups:
#    arrivals moved across a cache-fill boundary land in a neighboring
#    round, shifting mid-run round instants, intermediate cumulative byte
#    columns (bytes are metered at dispatch) and the exact model values.
#    Under zero noise the event frontier re-synchronizes after the cut, so
#    the single-job runs land exactly on everything *except* model values:
#    round sequence, final-row time/round/bytes, meters, pending multiset
#    and the server state machine are equal, while weights compare
#    allclose (the regrouped Eqs. 6-10 reduction mixes the same updates
#    into adjacent rounds; the gamma-mixing decay bounds the drift) and
#    the final accuracy within 0.05.  A multi-job fleet can additionally
#    shift one round completion across the final budget boundary, so the
#    fleet's uninterrupted comparison allows a +-1 round skew and a small
#    relative byte skew.

def _server_state_machine(srv):
    return (srv.t, srv.active, len(srv.cache))


def _assert_server_close(srv_a, srv_b, atol=0.2):
    assert _server_state_machine(srv_a) == _server_state_machine(srv_b)
    for la, lb in zip(jax.tree.leaves(srv_a.w), jax.tree.leaves(srv_b.w)):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.shape == lb.shape and np.all(np.isfinite(lb))
        np.testing.assert_allclose(la, lb, rtol=0, atol=atol)


def _assert_resume_bit_exact(h_cont, h_res, eng_cont, eng_res):
    """Layer 1: the restored engine vs the never-serialized continuation."""
    assert_histories_equal(h_cont, h_res)
    assert_engine_state_equal(eng_cont, eng_res)
    assert _server_state_machine(eng_cont.server) == \
        _server_state_machine(eng_res.server)
    for la, lb in zip(jax.tree.leaves(eng_cont.server.w),
                      jax.tree.leaves(eng_res.server.w)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_wave_cut_relaxed(h_full, h_res):
    """Layer 2 history contract: same round sequence, final row exact
    except the accuracy of the (allclose-only) weights."""
    assert len(h_full) == len(h_res)
    assert [a.round for a in h_full] == [b.round for b in h_res]
    a, b = h_full[-1], h_res[-1]
    assert (a.time, a.round) == (b.time, b.round)
    assert abs(a.accuracy - b.accuracy) <= 0.05
    assert (a.bytes_up, a.bytes_down,
            a.max_model_bytes_up, a.max_model_bytes_down) == \
           (b.bytes_up, b.bytes_down,
            b.max_model_bytes_up, b.max_model_bytes_down)


def _wave_resume_cfg(n, method, cohort, seed):
    return SimConfig(method=method, task="fmnist_cnn", n_devices=n,
                     c_fraction=1.0, gamma=0.25, epochs=1, batch_size=8,
                     p_s=0.25, p_q=8, seed=seed, cohort_size=cohort,
                     cohort_channel_iters=6, scheduler="batched",
                     handler_mode="wave",
                     compute=ComputeConfig(phi=float("inf")))


@pytest.mark.parametrize("method,cohort", [("teasq", 0), ("teasq", 3),
                                           ("fedasync", 0)])
def test_wave_engine_resume_parity(method, cohort, tmp_path):
    """Wave mode run(2) -> state_dict -> save_blob -> load -> run(4):
    bit-identical to the same engine continued past the save (layer 1),
    and equal to the uninterrupted wave run(4) on round sequence, final
    row, meters/stats, pending-event multiset and the server state
    machine, with allclose weights (layer 2 — see the section comment).
    The resume pin PR 9's wave handlers owed."""
    n = 8
    data, parts, w0 = _wave_setup(n, 0)
    cfg = _wave_resume_cfg(n, method, cohort, seed=0)
    full = make_sim(data, parts, w0, cfg)
    h_full = full.run(time_budget=4.0, eval_every=1)
    a = make_sim(data, parts, w0, cfg)
    a.run(time_budget=2.0, eval_every=1)
    path = str(tmp_path / "wave_engine.msgpack")
    save_blob(path, a.state_dict())
    b = make_sim(data, parts, w0, cfg)
    b.load_state(load_blob(path))
    h_res = b.run(time_budget=4.0, eval_every=1)
    h_cont = a.run(time_budget=4.0, eval_every=1)   # never serialized
    assert h_full[-1].round >= 1          # the run did aggregate
    _assert_resume_bit_exact(h_cont, h_res, a, b)
    assert _pending_events(a) == _pending_events(b)
    _assert_wave_cut_relaxed(h_full, h_res)
    assert_engine_state_equal(full, b)
    assert _pending_events(full) == _pending_events(b)
    _assert_server_close(full.server, b.server)


def test_wave_fleet_resume_parity(tmp_path):
    """The fleet analog: restoring a two-job wave fleet's blob replays
    the continued fleet bit-for-bit per task (layer 1); vs the
    uninterrupted fleet the comparison additionally tolerates one round
    completion shifted across the final budget boundary (layer 2 — the
    regrouped instants can move a completion past the budget, taking its
    eval row, round count and dispatch bytes with it)."""
    n = 12
    data, parts, w0 = _wave_setup(n, 1)

    def fresh():
        specs = [_wave_resume_cfg(n, "teasq", 0, seed=1),
                 _wave_resume_cfg(n, "fedasync", 3, seed=1)]
        return MultiTaskEngine([data, data], [parts, parts], [w0, w0],
                               FleetConfig(tasks=specs, n_devices=n,
                                           seed=1, scheduler="batched",
                                           handler_mode="wave",
                                           compute=ComputeConfig(
                                               phi=float("inf"))))

    full = fresh()
    h_full = full.run(time_budget=3.0, eval_every=1)
    a = fresh()
    a.run(time_budget=1.5, eval_every=1)
    path = str(tmp_path / "wave_fleet.msgpack")
    save_blob(path, a.state_dict())
    b = fresh()
    b.load_state(load_blob(path))
    h_res = b.run(time_budget=3.0, eval_every=1)
    h_cont = a.run(time_budget=3.0, eval_every=1)   # never serialized
    assert any(h[-1].round >= 1 for h in h_full)
    for h_c, h_r, rt_c, rt_r in zip(h_cont, h_res, a.runtimes, b.runtimes):
        _assert_resume_bit_exact(h_c, h_r, rt_c, rt_r)
    assert _pending_events(a) == _pending_events(b)
    for h_f, h_r, rt_f, rt_r in zip(h_full, h_res, full.runtimes,
                                    b.runtimes):
        assert abs(len(h_f) - len(h_r)) <= 1
        assert abs(rt_f.server.t - rt_r.server.t) <= 1
        assert abs(h_f[-1].accuracy - h_r[-1].accuracy) <= 0.05
        up_f, up_r = h_f[-1].bytes_up, h_r[-1].bytes_up
        assert abs(up_f - up_r) <= 0.05 * max(up_f, up_r)
        for la, lb in zip(jax.tree.leaves(rt_f.server.w),
                          jax.tree.leaves(rt_r.server.w)):
            la, lb = np.asarray(la), np.asarray(lb)
            assert la.shape == lb.shape and np.all(np.isfinite(lb))
            np.testing.assert_allclose(la, lb, rtol=0, atol=0.2)
