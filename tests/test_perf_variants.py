"""Correctness of the §Perf optimization variants: int8 KV cache,
sequence-sharded MQA decode, chunked-vocab loss."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["granite_34b", "qwen3_1_7b"])
def test_int8_kv_cache_decode_close(arch):
    cfg = get_smoke_config(arch)
    params = T.init_model(KEY, cfg)
    S = 10
    toks = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab, (2, S)),
                       jnp.int32)
    full, _ = T.forward(params, {"tokens": toks}, cfg)
    cache = T.init_decode_state(cfg, 2, S, dtype=jnp.float32, quantized=True)
    errs = []
    for t in range(S):
        dl, cache = T.decode_step(params, toks[:, t:t + 1], jnp.int32(t),
                                  cfg, cache)
        errs.append(float(jnp.abs(dl[:, 0] - full[:, t]).max()))
    # int8 KV: quantization-level tolerance, far tighter than logit scale
    scale = float(jnp.abs(full).max())
    assert max(errs) < 0.05 * scale


def test_chunked_loss_matches_dense():
    cfg = get_smoke_config("qwen3_1_7b")
    params = T.init_model(KEY, cfg)
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(2).randint(0, cfg.vocab, (2, 33)), jnp.int32)}
    l_dense, _ = T.lm_loss(params, batch, cfg)
    l_chunk, _ = T.lm_loss(params, batch, cfg, loss_chunk=8)  # ragged: 32/8
    np.testing.assert_allclose(float(l_dense), float(l_chunk), rtol=1e-5)
    # gradients agree too
    g1 = jax.grad(lambda p: T.lm_loss(p, batch, cfg)[0])(params)
    g2 = jax.grad(lambda p: T.lm_loss(p, batch, cfg, loss_chunk=8)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_chunked_loss_vlm():
    cfg = get_smoke_config("internvl2_2b")
    params = T.init_model(KEY, cfg)
    rng = np.random.RandomState(3)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (2, 17)), jnp.int32),
             "patches": jnp.asarray(rng.randn(2, cfg.n_patches, cfg.d_model),
                                    jnp.float32)}
    l_dense, _ = T.lm_loss(params, batch, cfg)
    l_chunk, _ = T.lm_loss(params, batch, cfg, loss_chunk=4)
    np.testing.assert_allclose(float(l_dense), float(l_chunk), rtol=1e-5)


SEQSHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.sharding.rules import Rules, use_rules

cfg = get_smoke_config("granite_34b")
params = T.init_model(jax.random.PRNGKey(0), cfg)
S = 16
toks = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab, (4, S)), jnp.int32)
full, _ = T.forward(params, {"tokens": toks}, cfg)
mesh = jax.make_mesh((2, 2), ("data", "model"))
cache = T.init_decode_state(cfg, 4, S, dtype=jnp.float32)
errs = []
with use_rules(Rules(mesh)), mesh:
    step = jax.jit(lambda p, t, pos, c: T.decode_step(p, t, pos, cfg, c, seq_shard_kv=True))
    for t in range(S):
        dl, cache = step(params, toks[:, t:t+1], jnp.int32(t), cache)
        errs.append(float(jnp.abs(dl[:,0]-full[:,t]).max()))
assert max(errs) < 5e-4, max(errs)
print("OK")
"""


def test_seqshard_decode_subprocess():
    r = subprocess.run([sys.executable, "-c", SEQSHARD_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
