"""Datacenter fed round (core/fed_step.py): math, schedules, mesh equivalence."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.fed_step import (FedConfig, approx_topk_threshold,
                                 compress_delta, decompress_delta,
                                 fed_wire_bytes, make_fed_train_step)
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _setup(schedule="gather_q", n_groups=4, local_steps=2):
    cfg = get_smoke_config("smollm_135m")
    params = T.init_model(KEY, cfg)
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg)[0]
    fed = FedConfig(n_groups=n_groups, local_steps=local_steps, lr=1e-2,
                    schedule=schedule)
    step = jax.jit(make_fed_train_step(loss_fn, fed))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (n_groups * local_steps * 2, 32)), jnp.int32)}
    stale = jnp.zeros((n_groups,), jnp.int32)
    return params, step, batch, stale


def test_fed_round_reduces_loss():
    params, step, batch, stale = _setup()
    p, losses = params, []
    for _ in range(6):
        p, m = step(p, batch, stale)
        losses.append(float(m["local_loss"]))
    assert losses[-1] < losses[0] - 0.02


def test_staleness_shrinks_mixing():
    params, step, batch, _ = _setup()
    _, m_fresh = step(params, batch, jnp.zeros(4, jnp.int32))
    _, m_stale = step(params, batch, jnp.full(4, 8, jnp.int32))
    assert float(m_stale["alpha_t"]) < float(m_fresh["alpha_t"])
    np.testing.assert_allclose(float(m_fresh["alpha_t"]), 0.6, atol=1e-5)
    np.testing.assert_allclose(float(m_stale["alpha_t"]), 0.6 * 9 ** -0.5,
                               atol=1e-5)


def test_schedules_agree_up_to_quantization():
    params, step_q, batch, stale = _setup("gather_q")
    _, step_f, _, _ = _setup("gather_f32")
    _, step_p, _, _ = _setup("psum")
    pq, _ = step_q(params, batch, stale)
    pf, _ = step_f(params, batch, stale)
    pp, _ = step_p(params, batch, stale)
    # exact: psum == gather_f32 (same math)
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    # lossy: gather_q within quantization error of f32
    for a, b in zip(jax.tree.leaves(pq), jax.tree.leaves(pf)):
        assert float(jnp.abs(a - b).max()) < 5e-4


def test_approx_topk_threshold_accuracy():
    x = jnp.abs(jnp.asarray(np.random.RandomState(1).randn(100000)
                            .astype(np.float32)))
    for p_s in (0.05, 0.25, 0.5):
        thr = approx_topk_threshold(x, p_s, iters=16)
        frac = float((x >= thr).mean())
        assert abs(frac - p_s) < 0.01


def test_compress_delta_roundtrip_error():
    fed = FedConfig(p_s=0.5, p_q=8)
    x = jnp.asarray(np.random.RandomState(2).randn(4096).astype(np.float32))
    lv, sc = compress_delta(x, fed)
    assert lv.dtype == jnp.int8
    y = decompress_delta(lv, sc, fed, jnp.float32)
    kept = np.abs(np.asarray(x)) >= np.quantile(np.abs(np.asarray(x)), 0.5)
    np.testing.assert_allclose(np.asarray(y)[kept], np.asarray(x)[kept],
                               atol=float(sc) / 127 + 1e-5)


def test_wire_bytes_math():
    params = {"w": jnp.zeros((1000,))}
    wb = fed_wire_bytes(params, FedConfig(p_s=0.25, p_q=8), n_groups=8)
    assert wb["dense_f32"] == 4 * 1000 * 8
    assert wb["dense_quant"] == 1000 * 8
    assert wb["compression_x"] > 5


MESH_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.core.fed_step import FedConfig, make_fed_train_step
from repro.sharding.rules import Rules, use_rules, param_shardings
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

cfg = get_smoke_config("phi3_5_moe_42b")  # exercises MoE EP path too
params = T.init_model(jax.random.PRNGKey(0), cfg)
loss_fn = lambda p, b: T.lm_loss(p, b, cfg)[0]
fed = FedConfig(n_groups=2, local_steps=1, lr=1e-2, schedule="gather_q")
step = make_fed_train_step(loss_fn, fed)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 32)), jnp.int32)}
stale = jnp.asarray([0, 2], jnp.int32)

# no-mesh reference
p_ref, m_ref = jax.jit(step)(params, batch, stale)

# 2x2 mesh (data=fed groups, model=TP/EP)
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = Rules(mesh)
with use_rules(rules), mesh:
    p_mesh, m_mesh = jax.jit(step)(params, batch, stale)

errs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_mesh))]
print("MAXERR", max(errs))
print("LOSSDIFF", abs(float(m_ref["local_loss"]) - float(m_mesh["local_loss"])))
assert max(errs) < 5e-3, errs
# The loss *metric* is looser than the params: the EP path drops tokens at
# finite expert capacity while _moe_dense_ref routes every token (no drops),
# so at smoke scale (128 tokens) the reported local_loss differs by ~1e-2
# even though the trained params agree to ~5e-5 above.
assert abs(float(m_ref["local_loss"]) - float(m_mesh["local_loss"])) < 2e-2
print("OK")
"""


def test_mesh_equivalence_subprocess():
    """The sharded fed round (shard_map gather + MoE EP) must match the
    no-mesh reference.  Runs in a subprocess because the 4-device host
    platform flag must be set before jax initializes."""
    r = subprocess.run([sys.executable, "-c", MESH_EQUIV_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
