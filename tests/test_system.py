"""End-to-end behaviour tests for the TEASQ-Fed system (paper claims at
reduced scale) — the integration layer above the unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dynamic import make_schedule
from repro.fl.protocols import (best_acc_within, make_setup,
                                profile_compression, run_method, time_to_acc,
                                train_global)


@pytest.fixture(scope="module")
def setup():
    # 20 devices / 6k samples: big enough for signal, small enough for CI
    return make_setup(n_devices=20, iid=True, seed=0, n_train=6000,
                      n_test=1500)


@pytest.fixture(scope="module")
def histories(setup):
    data, parts, w0 = setup
    out = {}
    # 20 devices: C=0.3 keeps 6 in flight (the paper's C=0.1 assumes N=100;
    # at N=20 it would leave only 2 devices training)
    out["tea"] = run_method("tea", data, parts, w0, time_budget=40.0,
                            epochs=2, eval_every=2, c_fraction=0.3)
    out["fedavg"] = run_method("fedavg", data, parts, w0, time_budget=40.0,
                               epochs=2, eval_every=2)
    return out


def test_async_beats_sync_in_rounds_per_time(histories):
    """Paper §5.2: TEA-Fed completes more aggregation rounds than FedAvg in
    equal virtual time (no straggler waits)."""
    assert histories["tea"][-1].round > histories["fedavg"][-1].round


def test_both_methods_learn(histories):
    for m, h in histories.items():
        assert h[-1].accuracy > 0.15, (m, h[-1].accuracy)


def test_tea_fed_accuracy_competitive(histories):
    """TEA-Fed must reach at least FedAvg-level accuracy within the budget
    (paper reports it strictly better; at tiny scale we assert >= - margin)."""
    tea = best_acc_within(histories["tea"], 40.0)
    avg = best_acc_within(histories["fedavg"], 40.0)
    assert tea >= avg - 0.08, (tea, avg)


def test_dynamic_compression_pipeline(setup):
    """Alg. 5 end-to-end: profile -> schedule -> run TEASQ; compressed wire
    must be smaller and accuracy must stay in range."""
    data, parts, w0 = setup
    # Alg. 5 profiles a TRAINED model (a random init is insensitive to
    # compression and the search would pick maximum compression)
    w_warm = train_global(data, parts, w0, time_budget=15.0, epochs=2,
                          c_fraction=0.3)
    si, qi, trace = profile_compression(w_warm, data, theta=0.05)
    sch = make_schedule(si, qi, total_rounds=30)
    h_sq = run_method("teasq", data, parts, w0, time_budget=30.0,
                      epochs=2, c_fraction=0.3, schedule=sch)
    h_tea = run_method("tea", data, parts, w0, time_budget=30.0, epochs=2,
                       c_fraction=0.3)
    assert h_sq[-1].bytes_up < h_tea[-1].bytes_up
    # aggressive early compression: assert stability (no collapse below
    # chance), not parity — at this 30s budget TEASQ is still in its
    # most-compressed phase (full parity shown in benchmarks/table3_6)
    import numpy as _np
    assert _np.isfinite(max(h.accuracy for h in h_sq))
    assert max(h.accuracy for h in h_sq) >= 0.09


def test_time_to_acc_helper():
    class H:
        def __init__(self, t, a):
            self.time, self.accuracy = t, a
    hist = [H(0, 0.1), H(5, 0.5), H(9, 0.8)]
    assert time_to_acc(hist, 0.5) == 5
    assert time_to_acc(hist, 0.9) is None
    assert best_acc_within(hist, 6) == 0.5
