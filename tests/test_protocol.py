"""Server state machine (Algs. 1-2), dynamic compression (Alg. 5), and the
event-driven simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dynamic import (DEFAULT_SET_Q, DEFAULT_SET_S,
                                CompressionSchedule, greedy_search,
                                make_schedule)
from repro.core.server import ServerConfig, TeasqServer
from repro.fl.protocols import make_setup, run_method


# -- C-fraction admission (Alg. 1 server side) ---------------------------
@pytest.mark.smoke
def test_c_fraction_gate():
    srv = TeasqServer({"w": jnp.zeros(2)}, ServerConfig(
        n_devices=100, c_fraction=0.1))
    grants = [srv.try_dispatch() for _ in range(15)]
    assert sum(g is not None for g in grants) == 10  # ceil(100*0.1)
    assert srv.active == 10
    # a completed upload frees a slot
    srv.receive({"w": jnp.ones(2)}, h=0, n_samples=10)
    assert srv.active == 9
    assert srv.try_dispatch() is not None


@pytest.mark.smoke
def test_cache_aggregates_at_K():
    srv = TeasqServer({"w": jnp.zeros(2)}, ServerConfig(
        n_devices=30, c_fraction=0.5, gamma=0.1, alpha=1.0))
    K = srv.cfg.cache_size
    assert K == 3
    for i in range(K - 1):
        assert not srv.receive({"w": jnp.ones(2)}, h=0, n_samples=10)
        assert srv.t == 0
    assert srv.receive({"w": jnp.ones(2)}, h=0, n_samples=10)
    assert srv.t == 1
    assert len(srv.cache) == 0
    np.testing.assert_allclose(np.asarray(srv.w["w"]), [1.0, 1.0], atol=1e-6)


# -- Algorithm 5 ---------------------------------------------------------
@pytest.mark.smoke
def test_greedy_search_respects_theta():
    """Synthetic accuracy surface: acc = 0.9 - penalties. The search must
    stop at the most compressed point within theta of baseline."""
    def eval_acc(p_s, p_q):
        pen_s = {1.0: 0.0, 0.5: 0.005, 0.25: 0.01, 0.1: 0.03,
                 0.05: 0.08, 0.01: 0.2}[p_s]
        pen_q = {32: 0.0, 16: 0.002, 8: 0.008, 4: 0.06}[p_q]
        return 0.9 - pen_s - pen_q

    si, qi, trace = greedy_search(eval_acc, theta=0.02)
    assert DEFAULT_SET_S[si] == 0.25       # 0.01 penalty ok, 0.03 too much
    # at p_s=0.25: + quant 16 (0.012 total ok); 8 -> 0.018 ok; 4 -> 0.07 no
    assert DEFAULT_SET_Q[qi] == 8
    assert len(trace) >= 3


@pytest.mark.smoke
def test_schedule_decays_toward_less_compression():
    sch = CompressionSchedule(p_s0_idx=3, p_q0_idx=2, step_size=10)
    p_s0, p_q0 = sch.at_round(0)
    p_s_end, p_q_end = sch.at_round(100)
    assert p_s0 < p_s_end and p_q0 < p_q_end
    assert (p_s_end, p_q_end) == (1.0, 32)  # fully decayed
    # monotone
    prev = (p_s0, p_q0)
    for t in range(0, 60, 10):
        cur = sch.at_round(t)
        assert cur[0] >= prev[0] and cur[1] >= prev[1]
        prev = cur


@pytest.mark.smoke
def test_make_schedule_starts_more_compressed():
    sch = make_schedule(si=1, qi=1, total_rounds=40)
    assert sch.p_s0_idx == 2 and sch.p_q0_idx == 2


# -- simulator (small end-to-end runs) ------------------------------------
@pytest.fixture(scope="module")
def tiny_setup():
    return make_setup(n_devices=10, iid=True, seed=0, n_train=1000, n_test=500)


def test_simulator_tea_improves_accuracy(tiny_setup):
    data, parts, w0 = tiny_setup
    hist = run_method("tea", data, parts, w0, time_budget=15.0, eval_every=1,
                      epochs=2)
    assert hist[-1].round >= 2
    assert max(h.accuracy for h in hist) > hist[0].accuracy + 0.02
    times = [h.time for h in hist]
    assert times == sorted(times)


def test_simulator_bytes_accounting(tiny_setup):
    data, parts, w0 = tiny_setup
    h_tea = run_method("tea", data, parts, w0, time_budget=6.0, epochs=1)
    h_sq = run_method("teastatic", data, parts, w0, time_budget=6.0,
                      epochs=1, p_s=0.25, p_q=8)
    assert h_sq[-1].max_model_bytes_up < h_tea[-1].max_model_bytes_up * 0.5


def test_simulator_fedavg_runs(tiny_setup):
    data, parts, w0 = tiny_setup
    hist = run_method("fedavg", data, parts, w0, time_budget=8.0,
                      epochs=1, devices_per_round=3)
    assert hist[-1].round >= 1
    assert np.isfinite(hist[-1].accuracy)


def test_simulator_fedasync_runs(tiny_setup):
    data, parts, w0 = tiny_setup
    hist = run_method("fedasync", data, parts, w0, time_budget=6.0, epochs=1)
    assert hist[-1].round >= 2


# -- per-device codec seam: channel_for(t, device_id) ---------------------
def test_channel_for_device_id_default_and_override(tiny_setup):
    """The codec seam carries the target device: the base policy is
    device-blind (and still answers the legacy one-arg call), while a
    strategy override can pick a per-device codec — the hook for
    bandwidth-tier-aware compression."""
    from repro.core.codecs import resolve_codec
    from repro.fl.engine import FLEngine
    from repro.fl.protocols import TeasqStrategy
    from repro.fl.simulator import SimConfig

    data, parts, w0 = tiny_setup
    cfg = SimConfig(method="teasq", n_devices=len(parts), p_s=0.25, p_q=8,
                    epochs=1, batch_size=8, seed=0, c_fraction=0.5,
                    gamma=0.25)

    # backward-compatible default: one-arg call works, device is ignored
    base = TeasqStrategy(cfg)
    assert base.channel_for(0).wire_bytes(w0) == \
        base.channel_for(0, device_id=3).wire_bytes(w0)

    class EvenDevicesUncompressed(TeasqStrategy):
        def __init__(self, cfg):
            super().__init__(cfg)
            self.seen = []

        def channel_for(self, t, device_id=None):
            self.seen.append(device_id)
            if device_id is not None and device_id % 2 == 0:
                return resolve_codec("identity")
            return super().channel_for(t, device_id)

    strat = EvenDevicesUncompressed(cfg)
    eng = FLEngine(data, parts, w0, cfg, strategy=strat)
    hist = eng.run(time_budget=2.0, eval_every=10 ** 9)
    assert strat.seen and all(d is not None for d in strat.seen)
    assert {d % 2 for d in strat.seen} == {0, 1}
    # even devices shipped dense f32, odd the compressed stream; every
    # dispatch was priced by exactly the codec its device was handed
    dense = resolve_codec("identity").wire_bytes(w0)
    compressed = base.channel_for(0).wire_bytes(w0)
    assert compressed < dense
    assert hist[-1].max_model_bytes_down == dense
    expected = sum(dense if d % 2 == 0 else compressed for d in strat.seen)
    assert hist[-1].bytes_down == expected
