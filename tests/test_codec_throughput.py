"""Smoke coverage for the codec throughput benchmark (scripts/tier1.sh runs
``pytest -m smoke``, which exercises the benchmark harness end to end on a
reduced grid without writing results)."""
import pytest

from benchmarks.codec_throughput import bench_codec, run

pytestmark = pytest.mark.smoke


def test_codec_throughput_smoke_grid():
    rows = run(reps=1, grid_ps=(0.25,), grid_pq=(8,), out_path=None)
    assert {r["codec"] for r in rows} == {"dense", "identity", "packed",
                                          "threshold", "packed_fused",
                                          "packed_host"}
    for r in rows:
        assert r["encode_mbps"] > 0
        # passthrough decodes (identity/threshold) report null, not a
        # timer-resolution pseudo-throughput
        if r["resolved"] in ("identity", "threshold"):
            assert r["decode_mbps"] is None
        else:
            assert r["decode_mbps"] > 0
        assert r["wire_bytes"] == r["expected_bytes"], r
        if r["resolved"] != "identity":
            assert r["wire_bytes"] < r["dense_bytes"]
    # the fused variant proved stream equality during the bench itself
    fused = next(r for r in rows if r["codec"] == "packed_fused")
    assert fused["bit_identical_to_host"] is True


def test_codec_throughput_merges_instead_of_clobbering(tmp_path):
    """A partial re-run must update its (codec, p_s, p_q) rows in place and
    keep every other recorded row (the engine_scale merge discipline)."""
    out = tmp_path / "codec_throughput.json"
    run(reps=1, grid_ps=(0.25,), grid_pq=(8,), codecs=("identity",),
        out_path=str(out))
    run(reps=1, grid_ps=(0.25,), grid_pq=(8,), codecs=("packed_fused",),
        out_path=str(out))
    import json
    rows = json.loads(out.read_text())
    assert {r["codec"] for r in rows} == {"identity", "packed_fused"}


def test_codec_throughput_prices_identity_dense():
    import jax
    from repro.models.cnn import init_cnn
    tree = init_cnn(jax.random.PRNGKey(0))
    row = bench_codec("identity", tree, 0.25, 8, reps=1)
    assert row["wire_bytes"] == row["dense_bytes"]
    assert row["compression_x"] == 1.0
