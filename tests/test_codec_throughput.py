"""Smoke coverage for the codec throughput benchmark (scripts/tier1.sh runs
``pytest -m smoke``, which exercises the benchmark harness end to end on a
reduced grid without writing results)."""
import pytest

from benchmarks.codec_throughput import bench_codec, run

pytestmark = pytest.mark.smoke


def test_codec_throughput_smoke_grid():
    rows = run(reps=1, grid_ps=(0.25,), grid_pq=(8,), out_path=None)
    assert {r["codec"] for r in rows} == {"dense", "identity", "packed",
                                          "threshold"}
    for r in rows:
        assert r["encode_mbps"] > 0
        # passthrough decodes (identity/threshold) report null, not a
        # timer-resolution pseudo-throughput
        if r["resolved"] in ("identity", "threshold"):
            assert r["decode_mbps"] is None
        else:
            assert r["decode_mbps"] > 0
        assert r["wire_bytes"] == r["expected_bytes"], r
        if r["resolved"] != "identity":
            assert r["wire_bytes"] < r["dense_bytes"]


def test_codec_throughput_prices_identity_dense():
    import jax
    from repro.models.cnn import init_cnn
    tree = init_cnn(jax.random.PRNGKey(0))
    row = bench_codec("identity", tree, 0.25, 8, reps=1)
    assert row["wire_bytes"] == row["dense_bytes"]
    assert row["compression_x"] == 1.0
