"""Compression (Alg. 3/4) unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (compress_tensor, decompress_tensor,
                                    pytree_dense_bytes, pytree_wire_bytes,
                                    quantize_levels, roundtrip_pytree,
                                    sparsify_quantize_dense, tensor_wire_bits,
                                    topk_mask, compress_pytree)


def test_topk_mask_keeps_k_largest():
    x = jnp.asarray(np.random.RandomState(0).randn(1000).astype(np.float32))
    mask = topk_mask(x, 0.1)
    k = int(mask.sum())
    assert 100 <= k <= 101  # ties
    kept_min = float(jnp.abs(x)[mask].min())
    dropped_max = float(jnp.abs(x)[~mask].max())
    assert kept_min >= dropped_max


def test_quantize_dequantize_error_bound():
    x = jnp.asarray(np.random.RandomState(1).randn(4096).astype(np.float32))
    for bits in (16, 8, 4):
        lv, sc = quantize_levels(x, bits)
        from repro.core.compression import dequantize_levels
        y = dequantize_levels(lv, sc, bits)
        L = 2 ** (bits - 1) - 1
        assert float(jnp.abs(y - x).max()) <= float(sc) / L * 0.5 + 1e-6


def test_roundtrip_preserves_top_values():
    rng = np.random.RandomState(2)
    tree = {"a": rng.randn(100, 50).astype(np.float32),
            "b": rng.randn(37).astype(np.float32)}
    out, nbytes = roundtrip_pytree(tree, 0.3, 8)
    dense = pytree_dense_bytes(tree)
    assert nbytes < dense * 0.45  # ~0.3*(8+13)/32 + overhead
    for k in tree:
        x, y = tree[k].reshape(-1), np.asarray(out[k]).reshape(-1)
        top = np.argsort(-np.abs(x))[: int(0.25 * x.size)]
        scale = np.abs(x).max()
        np.testing.assert_allclose(y[top], x[top], atol=scale / 127 * 1.5)


def test_paper_table7_size_reduction():
    """Table 7: TEASQ local-model wire size ~44% smaller than dense f32.
    With p_s=0.5, p_q=16 the packed size must land in that regime."""
    rng = np.random.RandomState(3)
    from repro.models.cnn import init_cnn
    w = init_cnn(jax.random.PRNGKey(0))
    dense = pytree_dense_bytes(w)
    c = compress_pytree(w, 0.5, 16, rng)
    wire = pytree_wire_bytes(c)
    red = 1 - wire / dense
    assert 0.2 < red < 0.6, f"reduction {red:.2f}"


@settings(max_examples=25, deadline=None)
@given(p_s=st.sampled_from([0.05, 0.1, 0.25, 0.5, 1.0]),
       p_q=st.sampled_from([4, 8, 16, 32]),
       n=st.integers(10, 2000))
def test_wire_bytes_monotone_and_bounded(p_s, p_q, n):
    """Property: wire size decreases with compression and never exceeds
    dense f32 (plus per-tensor scale overhead)."""
    rng = np.random.RandomState(n)
    x = rng.randn(n).astype(np.float32)
    c = compress_tensor(x, p_s, p_q, rng)
    bits = tensor_wire_bits(c)
    assert bits <= n * 64 + 32
    if p_s <= 0.25 and p_q <= 8:
        assert bits < n * 32  # strictly better than dense
    y = decompress_tensor(c)
    assert y.shape == x.shape
    assert np.isfinite(y).all()


@settings(max_examples=20, deadline=None)
@given(p_q=st.sampled_from([8, 16]), seed=st.integers(0, 1000))
def test_stochastic_quantization_unbiased(p_q, seed):
    """QSGD property: stochastic rounding is unbiased in expectation."""
    rng = np.random.RandomState(seed)
    x = np.full(20000, 0.377, np.float32)
    c = compress_tensor(x, 1.0, p_q, rng)
    y = decompress_tensor(c)
    assert abs(y.mean() - 0.377) < 2e-3


def test_dense_ingraph_matches_packed_semantics():
    """sparsify_quantize_dense (fed_step path, global-topk variant) ==
    compress->decompress for the same parameters."""
    x = jnp.asarray(np.random.RandomState(5).randn(512).astype(np.float32))
    y1 = np.asarray(sparsify_quantize_dense(x, 0.25, 8))
    c = compress_tensor(np.asarray(x), 0.25, 8)
    y2 = decompress_tensor(c)
    np.testing.assert_allclose(y1, y2, atol=1e-6)
