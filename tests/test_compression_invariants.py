"""Compression + codec invariants that hold without hypothesis (the
property-test modules tests/test_compression.py and tests/test_codecs.py skip
when hypothesis is absent): wire-size monotonicity in (p_s, p_q), lossless
round trip at the identity point, shape-only size prediction,
Pallas-kernel-vs-dense parity, and the codec-API acceptance invariants
(packed bytes == analytic price, packed == dense bit-for-bit, the
channel_for seam)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codecs import (CODECS, DenseRefCodec, IdentityCodec,
                               PackedBitstreamCodec, ThresholdGraphCodec,
                               resolve_codec)
from repro.core.compression import (compress_pytree, expected_pytree_wire_bytes,
                                    pytree_dense_bytes, pytree_wire_bytes,
                                    roundtrip_pytree, sparsify_quantize_dense,
                                    sparsify_quantize_threshold)
from repro.kernels.topk_quant import dequant, topk_quant
from repro.models.cnn import init_cnn

pytestmark = pytest.mark.smoke


@pytest.fixture(scope="module")
def tree():
    return init_cnn(jax.random.PRNGKey(7))


def test_wire_bytes_monotone_in_ps_and_pq(tree):
    rng = np.random.RandomState(0)
    sizes_s = [pytree_wire_bytes(compress_pytree(tree, p_s, 8, rng))
               for p_s in (0.01, 0.05, 0.1, 0.25, 0.5)]
    assert sizes_s == sorted(sizes_s)
    assert sizes_s[0] < sizes_s[-1]
    sizes_q = [pytree_wire_bytes(compress_pytree(tree, 0.25, p_q, rng))
               for p_q in (4, 8, 16, 32)]
    assert sizes_q == sorted(sizes_q)
    assert sizes_q[0] < sizes_q[-1]


def test_roundtrip_identity_at_no_compression(tree):
    w2, nbytes = roundtrip_pytree(tree, 1.0, 32, np.random.RandomState(0))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(w2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # packed framing overhead only (one f32 scale per tensor)
    dense = pytree_dense_bytes(tree)
    assert dense <= nbytes <= dense + 4 * len(jax.tree.leaves(tree))


def test_expected_wire_bytes_matches_actual(tree):
    """The deferred cohort path schedules arrivals from the shape-only size;
    it must agree exactly with the packed codec's accounting."""
    rng = np.random.RandomState(0)
    for p_s, p_q in [(0.25, 8), (0.5, 16), (1.0, 8), (0.1, 32), (1.0, 32)]:
        expected = expected_pytree_wire_bytes(tree, p_s, p_q)
        if p_s >= 1.0 and p_q >= 32:
            assert expected == pytree_dense_bytes(tree)   # simulator fast path
        else:
            actual = pytree_wire_bytes(compress_pytree(tree, p_s, p_q, rng))
            assert expected == actual, (p_s, p_q)


@pytest.mark.parametrize("p_s,bits", [(0.25, 8), (0.1, 8), (0.5, 4)])
def test_pallas_kernel_parity_with_dense(p_s, bits):
    """topk_quant + dequant vs the dense in-graph operator, one block so the
    kernel's block-local threshold approximates the same global Top-K."""
    rng = np.random.RandomState(42)
    x = jnp.asarray(rng.randn(4096).astype(np.float32))
    dense = np.asarray(sparsify_quantize_dense(x, p_s, bits))
    lv, sc = topk_quant(x, p_s=p_s, bits=bits, block=4096)
    kernel = np.asarray(dequant(lv, sc, bits, 4096, (4096,)))

    kept_dense = (dense != 0).mean()
    kept_kernel = (kernel != 0).mean()
    # explicit kept-fraction tolerance: binary-search threshold resolution
    # (2^-16 of the magnitude range) plus ties
    assert abs(kept_kernel - p_s) < 0.02
    assert abs(kept_dense - kept_kernel) < 0.02
    # where both keep a value they agree up to one quantization level
    both = (dense != 0) & (kernel != 0)
    assert both.mean() > p_s - 0.02
    level = float(np.abs(x).max()) / (2 ** (bits - 1) - 1)
    assert np.max(np.abs(dense[both] - kernel[both])) <= level + 1e-6


# ----------------------------------------------------------------------
# codec API (repro.core.codecs) acceptance invariants
# ----------------------------------------------------------------------
def test_bitpack_host_path_matches_jnp_kernels():
    """pack_segments/BitReader run the packing formula in plain numpy (the
    jit dispatch would dominate CPU encode); they must agree bit-for-bit
    with the jnp kernels field_to_bits/bits_to_field (the TPU path)."""
    from repro.kernels.bitpack import (BitReader, bits_to_field,
                                       field_to_bits, pack_segments)
    rng = np.random.RandomState(0)
    for width in (1, 2, 7, 8, 13, 16, 32):
        vals = rng.randint(0, 2 ** min(width, 31), size=57).astype(np.uint32)
        bits = np.asarray(field_to_bits(jnp.asarray(vals), width))
        payload = pack_segments([(vals, width)])
        np.testing.assert_array_equal(
            np.unpackbits(np.frombuffer(payload, np.uint8))[:bits.size], bits)
        got = BitReader(payload).read(len(vals), width)
        np.testing.assert_array_equal(got, vals)
        np.testing.assert_array_equal(
            np.asarray(bits_to_field(jnp.asarray(bits), width)), vals)


def test_codec_registry_and_identity_fast_path():
    assert set(CODECS) == {"identity", "dense", "threshold", "packed"}
    # the uncompressed point resolves to identity for every family (the
    # simulators' dense fast path), and instances are cached
    for name in CODECS:
        assert isinstance(resolve_codec(name, 1.0, 32), IdentityCodec)
    assert resolve_codec("packed", 0.25, 8) is resolve_codec("packed", 0.25, 8)
    with pytest.raises(ValueError, match="unknown codec"):
        resolve_codec("zstd", 0.25, 8)


@pytest.mark.parametrize("p_s,p_q", [(0.25, 8), (0.5, 16), (0.1, 4), (1.0, 8)])
def test_packed_bytes_equal_analytic_price_on_cnn(tree, p_s, p_q):
    """Acceptance: len() of the actual packed byte string equals the
    analytic shape-only price on the FMNIST CNN pytree, exactly."""
    codec = PackedBitstreamCodec(p_s, p_q)
    wire = codec.encode(tree)
    expected = expected_pytree_wire_bytes(tree, p_s, p_q)
    assert isinstance(wire.payload, bytes)
    assert len(wire.payload) == wire.nbytes == expected == codec.wire_bytes(tree)


@pytest.mark.parametrize("stochastic", [False, True])
def test_packed_roundtrip_matches_dense_ref_bitwise(tree, stochastic):
    """Acceptance: the packed stream decodes to exactly the DenseRefCodec
    result — same mask, same scale, same dequant levels, and the same RNG
    draw order under stochastic QSGD rounding."""
    rng_a = np.random.RandomState(5) if stochastic else None
    rng_b = np.random.RandomState(5) if stochastic else None
    y_p, nb_p = PackedBitstreamCodec(0.25, 8).roundtrip(tree, rng=rng_a)
    y_d, nb_d = DenseRefCodec(0.25, 8).roundtrip(tree, rng=rng_b)
    assert nb_p == nb_d == expected_pytree_wire_bytes(tree, 0.25, 8)
    for a, b in zip(jax.tree.leaves(y_p), jax.tree.leaves(y_d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_channel_for_seam_binds_policy_to_codec_family():
    from repro.fl.protocols import make_strategy
    from repro.fl.simulator import SimConfig
    cfg = SimConfig(n_devices=4, p_s=0.25, p_q=8, codec="packed")
    s = make_strategy("teasq", cfg)
    codec = s.channel_for(0)
    assert isinstance(codec, PackedBitstreamCodec)
    assert (codec.p_s, codec.p_q) == s.compression_at(0) == (0.25, 8)
    # uncompressed protocols get identity regardless of the family
    assert isinstance(make_strategy("tea", cfg).channel_for(0), IdentityCodec)
    thr = make_strategy("teasq", SimConfig(n_devices=4, p_s=0.25, p_q=8,
                                           codec="threshold",
                                           cohort_channel_iters=9))
    c_thr = thr.channel_for(0)
    assert isinstance(c_thr, ThresholdGraphCodec) and c_thr.iters == 9


@pytest.mark.parametrize("p_s,p_q", [(0.25, 8), (1.0, 8), (0.5, 32)])
def test_threshold_channel_parity_with_dense(p_s, p_q):
    """The engine's vectorized channel (binary-search threshold) must track
    the exact dense operator within the documented kept-fraction tolerance."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(8192).astype(np.float32))
    dense = np.asarray(sparsify_quantize_dense(x, p_s, p_q))
    approx = np.asarray(sparsify_quantize_threshold(x, p_s, p_q, iters=12))
    kept_a = (approx != 0).mean()
    if p_s < 1.0:
        assert abs(kept_a - p_s) < 0.01
    else:
        assert kept_a > 0.95
    both = (dense != 0) & (approx != 0)
    if p_q < 32:
        level = float(np.abs(x).max()) / (2 ** (p_q - 1) - 1)
        assert np.max(np.abs(dense[both] - approx[both])) <= level + 1e-6
    else:
        np.testing.assert_allclose(dense[both], approx[both], rtol=1e-6)
