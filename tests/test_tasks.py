"""FLTask registry conformance suite.

Every entry in ``repro.fl.tasks.TASKS`` must satisfy the task contract the
protocol stack assumes (see the tasks module docstring): finite loss and
gradients, a vectorized ``cohort_loss`` that collapses to the serial
``loss`` on a stacked singleton, an eval metric bounded in [0, 1], and a
param pytree every wire codec can round-trip.  Plus end-to-end: a non-CNN
task completes a short TEASQ run through the real bit-packed codec on both
simulator backends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codecs import resolve_codec
from repro.fl.protocols import make_setup, run_method
from repro.fl.tasks import TASKS, FLTask, get_task, register_task

TASK_NAMES = sorted(TASKS)


@pytest.fixture(scope="module")
def task_fixture():
    """(task, params, tiny train batch, test arrays) per registered task."""
    out = {}
    for name in TASK_NAMES:
        t = TASKS[name]
        data = t.make_data(32, 16, 0)
        params = t.init_params(jax.random.PRNGKey(0))
        batch = {"images": jnp.asarray(data["x_train"][:8]),
                 "labels": jnp.asarray(data["y_train"][:8])}
        out[name] = (t, params, batch, data)
    return out


# ----------------------------------------------------------------------
# registry basics
# ----------------------------------------------------------------------
@pytest.mark.smoke
def test_registry_has_cnn_and_two_more():
    assert "fmnist_cnn" in TASKS
    assert len(TASKS) >= 3


@pytest.mark.smoke
def test_get_task_rejects_unknown():
    with pytest.raises(ValueError, match="unknown task"):
        get_task("resnet152")


@pytest.mark.smoke
def test_register_rejects_duplicate():
    t = TASKS["fmnist_cnn"]
    with pytest.raises(ValueError, match="already registered"):
        register_task(dataclasses.replace(t))


# ----------------------------------------------------------------------
# per-task conformance
# ----------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("name", TASK_NAMES)
def test_loss_and_grad_finite(name, task_fixture):
    t, params, batch, _ = task_fixture[name]
    loss = t.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(t.loss)(params, batch)
    assert jax.tree.structure(grads) == jax.tree.structure(params)
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.smoke
@pytest.mark.parametrize("name", TASK_NAMES)
def test_cohort_singleton_matches_serial_loss(name, task_fixture):
    """A cohort of one device with the serial minibatch must produce the
    serial loss — the invariant that lets CohortTrainer substitute the
    vectorized path for SerialTrainer."""
    t, params, batch, _ = task_fixture[name]
    serial = float(t.loss(params, batch))
    stacked = jax.tree.map(lambda a: a[None], params)
    cohort = float(t.cohort_loss(stacked, batch["images"][None],
                                 batch["labels"][None]))
    np.testing.assert_allclose(cohort, serial, rtol=1e-6, atol=1e-7)


@pytest.mark.smoke
@pytest.mark.parametrize("name", TASK_NAMES)
def test_eval_metric_bounded(name, task_fixture):
    t, params, _, data = task_fixture[name]
    m = float(t.eval_metric(params, jnp.asarray(data["x_test"]),
                            jnp.asarray(data["y_test"])))
    assert 0.0 <= m <= 1.0


@pytest.mark.smoke
@pytest.mark.parametrize("name", TASK_NAMES)
def test_param_pytree_codec_roundtrip(name, task_fixture):
    """Every task's weights must survive the wire: the packed bitstream
    decode must be finite, shape-preserving, and bit-identical to the dense
    reference codec at the same operating point."""
    t, params, _, _ = task_fixture[name]
    rng_a, rng_b = np.random.RandomState(7), np.random.RandomState(7)
    dec_p, nbytes_p = resolve_codec("packed", 0.25, 8).roundtrip(
        params, rng=rng_a)
    dec_d, nbytes_d = resolve_codec("dense", 0.25, 8).roundtrip(
        params, rng=rng_b)
    assert nbytes_p == nbytes_d > 0
    assert jax.tree.structure(dec_p) == jax.tree.structure(params)
    for orig, a, b in zip(jax.tree.leaves(params), jax.tree.leaves(dec_p),
                          jax.tree.leaves(dec_d)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == np.asarray(orig).shape
        assert np.all(np.isfinite(a))
        np.testing.assert_array_equal(a, b)


@pytest.mark.smoke
@pytest.mark.parametrize("name", TASK_NAMES)
def test_make_data_contract(name, task_fixture):
    t, _, _, data = task_fixture[name]
    assert set(data) >= {"x_train", "y_train", "x_test", "y_test"}
    assert len(data["x_train"]) == len(data["y_train"]) == 32
    assert len(data["x_test"]) == len(data["y_test"]) == 16


# ----------------------------------------------------------------------
# end-to-end: non-CNN tasks through the whole protocol/codec stack
# ----------------------------------------------------------------------
def test_mlp_teasq_packed_both_backends():
    """A non-CNN task completes a short TEASQ run through the real
    bit-packed codec on both backends — and, since the serial path is
    task-generic, the two histories are bit-identical."""
    data, parts, w0 = make_setup(n_devices=4, iid=True, seed=0, n_train=96,
                                 n_test=48, task="fmnist_mlp")
    kw = dict(time_budget=3.0, epochs=1, batch_size=8, seed=0,
              codec="packed", task="fmnist_mlp", p_s=0.25, p_q=8)
    h_eng = run_method("teasq", data, parts, w0, backend="engine", **kw)
    h_leg = run_method("teasq", data, parts, w0, backend="legacy", **kw)
    assert h_eng[-1].round >= 1
    assert h_eng[-1].bytes_up > 0
    assert np.isfinite(h_eng[-1].accuracy)
    assert h_eng == h_leg


def test_transformer_lm_teasq_serial_and_cohort():
    """The transformer LM trains under TEASQ on the engine, both on the
    serial path and the vectorized cohort path (packed codec throughout)."""
    data, parts, w0 = make_setup(n_devices=4, iid=True, seed=0, n_train=64,
                                 n_test=32, task="transformer_lm")
    kw = dict(time_budget=2.0, epochs=1, batch_size=8, seed=0,
              codec="packed", task="transformer_lm", p_s=0.25, p_q=8,
              backend="engine")
    h = run_method("teasq", data, parts, w0, **kw)
    assert h[-1].round >= 1 and h[-1].bytes_up > 0
    assert np.isfinite(h[-1].accuracy)
    h_c = run_method("teasq", data, parts, w0, cohort_size=2, **kw)
    assert h_c[-1].round >= 1
    assert np.isfinite(h_c[-1].accuracy)


@pytest.mark.smoke
def test_lm_noniid_partition_has_label_skew():
    """The LM's pseudo-labels (leading-token buckets) must drive the paper's
    non-IID split — all-zero placeholder labels used to crash the
    partitioner."""
    data, parts, _ = make_setup(n_devices=8, iid=False, seed=0, n_train=400,
                                n_test=40, task="transformer_lm")
    labels = data["y_train"]
    assert len(np.unique(labels)) == 10
    for p in parts:
        assert len(set(labels[p])) == 2       # classes_per_device


def test_moon_requires_features():
    """Tasks without a representation head fail fast on MOON instead of
    producing a confusing trace inside the contrastive term."""
    data, parts, w0 = make_setup(n_devices=4, iid=True, seed=0, n_train=64,
                                 n_test=32, task="transformer_lm")
    with pytest.raises(ValueError, match="features"):
        run_method("moon", data, parts, w0, time_budget=1.0, epochs=1,
                   batch_size=8, seed=0, task="transformer_lm",
                   devices_per_round=2, backend="engine")


@pytest.mark.smoke
def test_task_is_frozen():
    """Function attributes must be stable objects (static jit args)."""
    t = get_task("fmnist_cnn")
    assert isinstance(t, FLTask)
    with pytest.raises(dataclasses.FrozenInstanceError):
        t.loss = None
    assert get_task("fmnist_cnn").loss is t.loss
