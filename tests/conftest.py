"""Shared fixtures + run-and-compare helpers for the parity suites.

The history-comparison loop used to live inside tests/test_engine_parity.py;
it is factored out here so the backend-parity suite (engine vs legacy
simulator) and the scheduler-parity suite (batched vs heap engine,
tests/test_batched_engine.py) assert bit-equality through ONE shared
implementation instead of drifting copies.

``tiny_setup`` is the canonical parity workload (8 devices, tiny synthetic
FMNIST CNN, seed 3) — the same config ``scripts/dump_pinned_histories.py``
records into tests/data/pinned_histories.json, cross-checked by the pinned
tests so the fixture and the suites cannot drift apart silently.
"""
import os

import numpy as np
import pytest

from repro.fl.protocols import make_setup, run_method

PINNED_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "pinned_histories.json")

# the generation config of the pinned fixture (see dump_pinned_histories.py)
TINY_SETUP = dict(n_devices=8, iid=True, seed=3, n_train=640, n_test=320)
TINY_RUN_KW = dict(time_budget=4.0, epochs=1, seed=3)


@pytest.fixture(scope="session")
def tiny_setup():
    """(data, partitions, w0) for the canonical 8-device parity workload."""
    return make_setup(**TINY_SETUP)


def assert_histories_equal(h_a, h_b):
    """Field-by-field bit-equality of two LogEntry histories."""
    assert len(h_a) == len(h_b)
    for a, b in zip(h_a, h_b):
        assert a.time == b.time
        assert a.round == b.round
        assert a.accuracy == b.accuracy
        assert a.bytes_up == b.bytes_up
        assert a.bytes_down == b.bytes_down
        assert a.max_model_bytes_up == b.max_model_bytes_up
        assert a.max_model_bytes_down == b.max_model_bytes_down


def assert_engine_state_equal(eng_a, eng_b):
    """Beyond the logged history: the two engines' channel meters (totals,
    maxima, per-tier dicts), per-device completion counts, scenario
    counters, and liveness must agree — the observable footprint of the
    event order."""
    ca, cb = eng_a.channel, eng_b.channel
    assert (ca.bytes_up, ca.bytes_down) == (cb.bytes_up, cb.bytes_down)
    assert (ca.max_up, ca.max_down) == (cb.max_up, cb.max_down)
    assert ca.tier_up == cb.tier_up
    assert ca.tier_down == cb.tier_down
    sa, sb = eng_a.stats, eng_b.stats
    assert (sa.dispatches, sa.completions, sa.dropouts,
            sa.transient_failures, sa.redispatched) == \
           (sb.dispatches, sb.completions, sb.dropouts,
            sb.transient_failures, sb.redispatched)
    assert np.array_equal(sa.completed_per_device, sb.completed_per_device)
    assert np.array_equal(eng_a.devices.alive, eng_b.devices.alive)


def run_tiny(method, setup, **kw):
    """One engine-backend run of the canonical parity workload (the shared
    TINY_RUN_KW, overridable per call)."""
    data, parts, w0 = setup
    merged = {**TINY_RUN_KW, "backend": "engine", **kw}
    return run_method(method, data, parts, w0, **merged)


def run_both_backends(method, setup, **kw):
    """(engine history, legacy history) on the canonical workload."""
    return (run_tiny(method, setup, **kw),
            run_tiny(method, setup, backend="legacy", **kw))


def run_both_schedulers(method, setup, **kw):
    """(heap history, batched history) on the canonical workload."""
    return (run_tiny(method, setup, scheduler="heap", **kw),
            run_tiny(method, setup, scheduler="batched", **kw))
