"""The documentation front door stays true.

Parses every fenced code block in README.md and docs/*.md, extracts the
shell commands, and verifies that each referenced entry point is a real
file and each ``--flag`` a command passes actually appears in that entry
point's argparse source.  One subprocess smoke additionally proves the
end-to-end example's ``--help`` parses.  Runs as part of scripts/tier1.sh
(step 3), so a doc command cannot silently rot when code moves.
"""
import os
import re
import shlex
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
# commands whose first token we know how to resolve; everything else in a
# fenced block (output samples, pseudo-layouts) is ignored
RUNNABLE = ("python", "python3", "pip", "scripts/", "bash")


def _doc_files():
    docs = [os.path.join(REPO, "README.md")]
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        docs += [os.path.join(docs_dir, f) for f in sorted(os.listdir(docs_dir))
                 if f.endswith(".md")]
    return docs


def iter_doc_commands():
    """Yield (doc, line) for every runnable command in a fenced block."""
    for doc in _doc_files():
        with open(doc) as f:
            text = f.read()
        for block in FENCE_RE.findall(text):
            for line in block.splitlines():
                line = line.strip()
                # drop env-var prefixes (PYTHONPATH=src python -m ...)
                stripped = line
                while re.match(r"^[A-Za-z_][A-Za-z0-9_]*=\S+\s+", stripped):
                    stripped = stripped.split(None, 1)[1]
                if stripped.startswith(RUNNABLE):
                    yield os.path.relpath(doc, REPO), stripped


def _resolve_target(argv):
    """The source file a documented command runs, or None when external
    (pip, python -m pytest, bash -c ...)."""
    prog = argv[0]
    if prog in ("pip", "pip3"):
        return None
    if prog == "bash":
        argv = argv[1:]
        prog = argv[0] if argv else ""
    if prog.startswith("scripts/") or prog.endswith(".sh"):
        return prog
    # python [-m mod | path.py]
    rest = argv[1:]
    if rest and rest[0] == "-m":
        mod = rest[1]
        if mod.split(".")[0] in ("pytest", "pip"):
            return None
        cand = os.path.join(*mod.split(".")) + ".py"
        for root in ("", "src"):
            if os.path.exists(os.path.join(REPO, root, cand)):
                return os.path.join(root, cand)
        return cand   # will fail the existence assert with a useful name
    for tok in rest:
        if tok.endswith(".py"):
            return tok
    return None


@pytest.mark.smoke
def test_docs_front_door_exists():
    assert os.path.exists(os.path.join(REPO, "README.md"))
    assert os.path.exists(os.path.join(REPO, "docs", "WIRE_FORMAT.md"))
    readme = open(os.path.join(REPO, "README.md")).read()
    # the README documents every registry by name
    for token in ("STRATEGIES", "CODECS", "TASKS", "POLICIES",
                  "tier_aware", "packed", "docs/WIRE_FORMAT.md"):
        assert token in readme, f"README.md no longer mentions {token!r}"


@pytest.mark.smoke
def test_doc_commands_reference_real_files_and_flags():
    commands = list(iter_doc_commands())
    assert len(commands) >= 5, "docs lost their runnable quickstart commands"
    checked_flags = 0
    for doc, line in commands:
        argv = shlex.split(line)
        target = _resolve_target(argv)
        if target is None:
            continue
        path = os.path.join(REPO, target)
        assert os.path.exists(path), f"{doc}: {line!r} references missing " \
                                     f"{target}"
        src = open(path).read()
        for tok in argv[1:]:
            if tok.startswith("--"):
                flag = tok.split("=")[0]
                assert flag in src, f"{doc}: {line!r} passes {flag}, which " \
                                    f"{target} does not define"
                checked_flags += 1
    assert checked_flags >= 5, "doc commands stopped exercising flags"


@pytest.mark.smoke
def test_example_help_parses():
    """The README's main entry point must import and parse --help — the
    one subprocess this suite affords (fresh interpreter + jax import)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "fl_end_to_end.py"),
         "--help"], capture_output=True, text=True, timeout=300, env=env,
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    for flag in ("--task", "--codec", "--codec-policy", "--backend",
                 "--cohort"):
        assert flag in out.stdout, f"--help lost {flag}"
