"""Fused one-pass wire emitter (repro.kernels.fused_pack) bit-equality pins.

Three executions of Alg. 3's packed serialization must produce the SAME byte
string: the multi-pass host oracle (``PackedBitstreamCodec(fused=False)``,
built on ``compress_tensor`` + ``pack_segments``), the vectorized numpy twin
(``pack_leaves_host`` — the production CPU path behind ``fused=True``), and
the Pallas kernel run under the interpreter (``pack_leaves_pallas`` — the
body that lowers to TPU ``pallas_call``).  The always-running deterministic
grid lives here and in tests/test_kernels.py; the hypothesis suite in
tests/test_fused_pack_properties.py additionally drives tie-heavy and
adversarial shapes.  On top of stream
equality, the fused-codec teasq history must stay byte-identical to the
frozen fixture tests/data/pinned_histories.json on both backends — the
end-to-end guarantee that the fast path cannot perturb protocol runs.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from conftest import PINNED_PATH, TINY_SETUP, assert_histories_equal, run_method

from repro.core.codecs import PackedBitstreamCodec, resolve_codec
from repro.core.compression import expected_pytree_wire_bytes
from repro.kernels.bitpack import pack_segments
from repro.kernels.fused_pack import (concat_bitstreams, pack_leaves_host,
                                      pack_leaves_pallas)
from repro.kernels.ops import fused_wire_encode

GRID_PS = (0.01, 0.1, 0.25, 1.0)          # 1.0 = dense fallback (k == n)
GRID_PQ = (2, 8, 32)                      # 32 = uncompressed values (raw f32)


def _tree(seed: int, n: int = 1500):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(n // 30, 30).astype(np.float32),
            "b": rng.randn(max(1, n // 100)).astype(np.float32),
            "s": np.float32(rng.randn())}


# ----------------------------------------------------------------------
# always-run deterministic grid (smoke: CI's fused slice, with the kernel
# half of the grid in tests/test_kernels.py)
# ----------------------------------------------------------------------
@pytest.mark.smoke
@pytest.mark.parametrize("p_s", GRID_PS)
@pytest.mark.parametrize("p_q", GRID_PQ)
def test_fused_paths_match_oracle_bitwise(p_s, p_q):
    """host twin == interpret-mode Pallas kernel == multi-pass oracle, and
    the length equals the analytic price at every sparse grid point."""
    tree = _tree(seed=int(p_s * 100) + p_q)
    leaves = jax.tree.leaves(tree)
    oracle = PackedBitstreamCodec(p_s, p_q, fused=False).encode(tree).payload
    assert pack_leaves_host(leaves, p_s, p_q) == oracle
    assert pack_leaves_pallas(leaves, p_s, p_q, interpret=True) == oracle
    if p_s < 1.0 or p_q < 32:             # dense point: price excludes scales
        assert len(oracle) == expected_pytree_wire_bytes(tree, p_s, p_q)


@pytest.mark.smoke
def test_fused_codec_auto_select_and_oracle_fallback():
    """fused=True encodes deterministically via the fused emitter, falls back
    to the oracle pipeline under stochastic rounding (rng is not None), and
    both decode to the oracle's trees."""
    tree = _tree(seed=5)
    fused = PackedBitstreamCodec(0.1, 8)            # fused defaults True
    oracle = PackedBitstreamCodec(0.1, 8, fused=False)
    assert fused.fused and resolve_codec("packed", 0.1, 8).fused
    wf, wo = fused.encode(tree), oracle.encode(tree)
    assert wf.payload == wo.payload
    for a, b in zip(jax.tree.leaves(fused.decode(wf)),
                    jax.tree.leaves(oracle.decode(wo))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # stochastic path: identical draws -> identical bytes regardless of fused
    sf = fused.encode(tree, rng=np.random.RandomState(3)).payload
    so = oracle.encode(tree, rng=np.random.RandomState(3)).payload
    assert sf == so


@pytest.mark.smoke
def test_fused_ties_break_to_smallest_index():
    """Duplicate magnitudes straddling the k-th place: every path must pick
    the canonical smallest-index survivors (WIRE_FORMAT.md, Determinism)."""
    rng = np.random.RandomState(11)
    vals = rng.choice([0.0, 0.25, -0.25, 0.5, -0.5], size=700)
    tree = [vals.astype(np.float32).reshape(35, 20)]
    for p_s in (0.05, 0.3, 0.6):
        oracle = PackedBitstreamCodec(p_s, 4, fused=False).encode(tree).payload
        assert pack_leaves_host(tree, p_s, 4) == oracle
        assert pack_leaves_pallas(tree, p_s, 4, interpret=True) == oracle


@pytest.mark.smoke
def test_fused_wire_encode_backends_agree():
    tree = _tree(seed=9)
    host = fused_wire_encode(tree, 0.1, 8, backend="host")
    interp = fused_wire_encode(tree, 0.1, 8, backend="interpret")
    auto = fused_wire_encode(tree, 0.1, 8)          # host on this container
    assert host == interp == auto
    with pytest.raises(ValueError):
        fused_wire_encode(tree, 0.1, 8, backend="gpu")


@pytest.mark.smoke
def test_concat_bitstreams_odd_and_empty_parts():
    """Bit-level joining at arbitrary (non-byte, non-word) offsets, with
    empty slices interleaved, equals one global pack_segments pass."""
    rng = np.random.RandomState(0)
    segs, parts = [], []
    for width, count in ((3, 5), (32, 2), (1, 13), (0, 0), (17, 4), (7, 1)):
        v = rng.randint(0, 2 ** max(width, 1), size=count).astype(np.uint32)
        if count:
            segs.append((v, width))
        parts.append((pack_segments([(v, width)] if count else []),
                      width * count))
    assert concat_bitstreams(parts) == pack_segments(segs)
    assert concat_bitstreams([]) == b""


# ----------------------------------------------------------------------
# end-to-end: fused codec cannot perturb protocol histories
# ----------------------------------------------------------------------
def test_fused_codec_history_pinned_both_backends(tiny_setup):
    """teasq with the fused packed codec, on BOTH backends, must replay the
    frozen pre-fused fixture byte-for-byte: engines pass the sim RNG into
    encode (stochastic QSGD), so the codec takes the oracle pipeline and the
    LogEntry history — times, rounds, accuracies, byte counters — is
    bit-identical to the dense-codec fixture history."""
    with open(PINNED_PATH) as f:
        pinned = json.load(f)
    assert pinned["setup"] == TINY_SETUP
    data, parts, w0 = tiny_setup
    kw = dict(pinned["run_kw"], **pinned["runs"]["teasq"])
    for backend in ("engine", "legacy"):
        hist = run_method("teasq", data, parts, w0, backend=backend,
                          codec="packed", **kw)
        got = [dataclasses.asdict(h) for h in hist]
        assert got == pinned["histories"]["teasq"], \
            f"fused packed codec drifted the {backend} teasq history"
